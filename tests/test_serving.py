"""Serving-layer gate (docs/serving.md): a subscriber-fed replica must be
byte-identical to a cold ``restore(step)`` at every committed step — the
differential oracle — and must never expose a torn table under faults,
corruption, or concurrent readers.

* differential freshness: every step, including a forced full-checkpoint
  boundary and a 2→3 reshard mid-stream;
* gap collapse: missed steps catch up in ONE plan;
* fault soak: seeded transport faults + a mid-apply kill; replica serves
  old-or-new only and converges once faults clear (nightly widens the
  seed grid via ``CNR_SERVE_SOAK_SEEDS``);
* double-buffer concurrency: 8 reader threads hammer ``lookup()`` during
  continuous applies — every batch is internally consistent with exactly
  one published version;
* manifest cache: steady-state polling is O(1) store reads (counter-
  proven), each new step costs exactly one manifest get.
"""

import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore
from repro.core import manifest as mf
from repro.core import metrics as metrics_mod
from repro.core.remote_store import (
    FaultSpec,
    RemoteObjectStore,
    RetryPolicy,
    ServerTransport,
    wrap_faulty,
)
from repro.core.snapshot import Snapshot
from repro.core.storage import LocalFSStore
from repro.serve import CheckpointSubscriber, EmbeddingServer, ManifestCache
from test_store_concurrency import hammer

FAST_RETRY = RetryPolicy(attempts=8, base_s=0.0005, cap_s=0.005)


class Driver:
    """Minimal training-job stand-in: owns the model arrays, mutates a
    random row subset per step, saves through a real manager. Supports a
    forced full boundary (policy-state reset, the only way ``consecutive``
    re-baselines) and a mid-stream reshard (new manager, new layout)."""

    def __init__(self, store, policy="consecutive", rows=160, dim=4,
                 seed=0, num_hosts=1):
        self.store = store
        self.rng = np.random.default_rng(seed)
        self.tabs = {
            "emb0": self.rng.normal(size=(rows, dim)).astype(np.float32),
            "emb1": self.rng.normal(size=(rows + 37, dim))
            .astype(np.float32),
        }
        self.policy = policy
        self.step_no = 0
        self.mgr = self._make_mgr(num_hosts)

    def _make_mgr(self, num_hosts):
        return CheckNRunManager(self.store, CheckpointConfig(
            policy=self.policy, quant=None, async_write=False,
            chunk_rows=64, keep_latest=20, num_hosts=num_hosts))

    def step(self, frac=0.08):
        self.step_no += 1
        touched = {}
        for name, arr in self.tabs.items():
            n = max(1, int(arr.shape[0] * frac))
            idx = self.rng.choice(arr.shape[0], size=n, replace=False)
            arr[idx] += self.rng.normal(size=(n, arr.shape[1])) \
                .astype(np.float32)
            t = np.zeros(arr.shape[0], bool)
            t[idx] = True
            touched[name] = t
        dense = {"mlp/w": self.rng.normal(size=(6, 6)).astype(np.float32)}
        self.mgr.save(Snapshot(
            step=self.step_no,
            tables={k: v.copy() for k, v in self.tabs.items()},
            row_state={n: {} for n in self.tabs},
            touched=touched, dense=dense, extra={}), block=True)
        return self.step_no

    def force_full_next(self):
        self.mgr.policy.state.baseline_step = None

    def reshard(self, num_hosts):
        self.mgr.close()
        self.mgr = self._make_mgr(num_hosts)
        self.mgr.resync_from(self.step_no)

    def close(self):
        self.mgr.close()


def cold_restore(store, step):
    """The differential oracle: a FRESH reader manager's restore(step).
    (Never the writer's manager — restore() resyncs policy state and
    would change the writer's subsequent full/incremental decisions.)"""
    mgr = CheckNRunManager(store, CheckpointConfig(async_write=False))
    try:
        return mgr.restore(step)
    finally:
        mgr.close()


def assert_serves_exactly(sub, store, step):
    """Served tables and dense params byte-identical to restore(step)."""
    ref = cold_restore(store, step)
    with sub.server.pinned() as v:
        assert v.step == step
        for name, want in ref.tables.items():
            got = v.lookup(name, np.arange(want.shape[0]))
            np.testing.assert_array_equal(got, want, err_msg=name)
        for name, want in ref.dense.items():
            np.testing.assert_array_equal(v.dense(name), want,
                                          err_msg=name)


# ------------------------------------------------------- differential gate
def test_differential_every_step_incl_full_boundary():
    store = InMemoryStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        for i in range(8):
            if i == 4:
                drv.force_full_next()  # full-checkpoint boundary mid-run
            step = drv.step()
            assert sub.poll_once() is True
            assert_serves_exactly(sub, store, step)
    finally:
        drv.close()
    assert mf.load(store, 5).kind == "full"
    assert mf.load(store, 6).kind == "incremental"
    m = sub.metrics()
    assert m["state"] == "live" and m["lag_steps"] == 0
    # steps 2-4 and 6-8 ride the delta path; 1 and the boundary resync
    assert m["incremental_refreshes_total"] == 6
    assert m["full_syncs_total"] == 2


def test_differential_across_reshard_2_to_3():
    store = InMemoryStore()
    drv = Driver(store, num_hosts=2)
    sub = CheckpointSubscriber(store)
    try:
        for _ in range(3):
            step = drv.step()
            assert sub.poll_once()
            assert_serves_exactly(sub, store, step)
        drv.reshard(3)  # grow mid-stream; chain now spans two layouts
        for _ in range(3):
            step = drv.step()
            assert sub.poll_once()
            assert_serves_exactly(sub, store, step)
    finally:
        drv.close()
    assert mf.load(store, 6).kind == "incremental", \
        "reshard must not force a re-baseline"
    m = sub.metrics()
    # the layout change is invisible to the subscriber: chunk row indices
    # are global, so post-reshard increments still apply as deltas
    assert m["incremental_refreshes_total"] == 5
    assert m["full_syncs_total"] == 1


def test_gap_collapses_into_one_plan():
    store = InMemoryStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        first = drv.step()
        assert sub.poll_once()
        for _ in range(4):  # subscriber misses these entirely
            last = drv.step()
    finally:
        drv.close()
    gets_before = store.counters.snapshot()["get_ops"]
    assert sub.poll_once()
    gets_used = store.counters.snapshot()["get_ops"] - gets_before
    assert sub.applied_step == last
    m = sub.metrics()
    assert m["applied_steps_total"] == 2  # one initial sync + ONE catch-up
    assert m["incremental_refreshes_total"] == 1
    # the catch-up fetched only the gap's manifests + chunks, no re-fetch
    # of the already-applied baseline
    chain = mf.recovery_chain(store, last)
    suffix = [man for man in chain if man.step > first]
    expected_gets = len(suffix) + sum(
        len(rec.chunks) for man in suffix for rec in man.tables.values()
    ) + len(chain[-1].dense)
    assert gets_used == expected_gets
    assert_serves_exactly(sub, store, last)


# ------------------------------------------------------------ fault soak
SOAK_SEEDS = range(31, 31 + int(os.environ.get("CNR_SERVE_SOAK_SEEDS", "2")))


@pytest.mark.parametrize("seed", SOAK_SEEDS)
def test_subscriber_fault_soak_never_torn_then_converges(seed):
    """Writer commits over a clean transport; the subscriber's transport
    injects seeded faults. Whatever a poll's outcome, the replica serves
    EXACTLY some committed step's state (old or new, never a mix); when
    faults clear it converges to the head."""
    backing = InMemoryStore()
    writer_store = RemoteObjectStore(ServerTransport(backing),
                                     retry=FAST_RETRY)
    sub_store = RemoteObjectStore(ServerTransport(backing),
                                  retry=RetryPolicy(attempts=3,
                                                    base_s=0.0005,
                                                    cap_s=0.003))
    inj = wrap_faulty(sub_store, FaultSpec(
        seed=seed, error_rate=0.25, slow_rate=0.05, slow_s=0.0005,
        list_lag=1))
    drv = Driver(writer_store, seed=seed)
    sub = CheckpointSubscriber(sub_store)
    try:
        for _ in range(6):
            drv.step()
            sub.poll_once()  # may fail mid-apply — that's the point
            if sub.applied_step is not None:
                assert_serves_exactly(sub, writer_store, sub.applied_step)
        assert inj.injected > 0, "soak row exercised no faults"
        # clear faults: must converge to the head within a few polls
        inj.spec = FaultSpec(seed=seed)
        head = mf.latest_step(writer_store)
        for _ in range(6):
            if sub.applied_step == head:
                break
            sub.poll_once()
        assert sub.applied_step == head
        assert sub.health.state == "live"
        assert_serves_exactly(sub, writer_store, head)
    finally:
        drv.close()


class KillSwitchStore(InMemoryStore):
    """Raises on the Nth get() once — a deterministic mid-apply death."""

    def __init__(self):
        super().__init__()
        self.fail_at = None
        self._gets = 0

    def get(self, key):
        self._gets += 1
        if self.fail_at is not None and self._gets >= self.fail_at:
            self.fail_at = None
            raise ConnectionResetError("mid-apply kill")
        return super().get(key)


def test_mid_apply_kill_serves_old_version_then_recovers():
    store = KillSwitchStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        first = drv.step(frac=0.5)
        assert sub.poll_once()
        drv.step(frac=0.5)
        last = drv.step(frac=0.5)
    finally:
        drv.close()
    # 2 manifest gets (steps 3 and 2; step 1 is cached) happen first, so
    # +3 lands inside the chunk stream: a true mid-apply death
    store.fail_at = store._gets + 3
    assert sub.poll_once() is False
    assert sub.health.state == "retrying"
    assert sub.errors_total >= 1
    # replica still serves the OLD step, untorn
    assert sub.server.step == first
    assert_serves_exactly(sub, store, first)
    # next poll (fault cleared) converges; the aborted rows were repaired
    # from the front buffer before the retry scattered over them
    assert sub.poll_once() is True
    assert sub.applied_step == last
    assert_serves_exactly(sub, store, last)


def test_corruption_holds_last_good_version_typed():
    store = InMemoryStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        first = drv.step()
        assert sub.poll_once()
        second = drv.step()
    finally:
        drv.close()
    man = mf.load(store, second)
    key = next(iter(man.tables.values())).chunks[0].key
    good = store.get(key)
    flipped = good[:-2] + bytes([good[-2] ^ 0xFF, good[-1] ^ 0xFF])
    store.put(key, flipped)
    for _ in range(2):  # held state is sticky across polls
        assert sub.poll_once() is False
        assert sub.health.state == "held"
        assert "corrupt" in (sub.health.reason or "").lower() \
            or "mismatch" in (sub.health.reason or "").lower()
    assert sub.holds_total == 2
    assert sub.server.step == first
    assert_serves_exactly(sub, store, first)
    store.put(key, good)  # blob repaired (e.g. re-replicated)
    assert sub.poll_once() is True
    assert sub.health.state == "live"
    assert_serves_exactly(sub, store, second)


# ------------------------------------------------- double-buffer hammering
def test_lookup_consistent_under_continuous_apply():
    """8 reader threads vs one applier. Every row of every table is set to
    the publishing version's value, so any torn batch (rows from two
    versions, or tables from two versions under one pin) is detectable as
    a mixed-value read."""
    rows, dim, n_versions = 256, 4, 120
    server = EmbeddingServer()
    server.install({"emb0": np.zeros((rows, dim), np.float32),
                    "emb1": np.zeros((rows, dim), np.float32)},
                   {}, step=0)
    dirty = {"emb0": [[0, rows]], "emb1": [[0, rows]]}
    stop = threading.Event()
    published = [0]

    def applier():
        try:
            for v in range(1, n_versions + 1):
                back = server.begin_apply()
                back["emb0"][: rows // 2] = v  # torn window on purpose:
                back["emb1"][:] = v            # emb1 full, emb0 half...
                back["emb0"][rows // 2:] = v   # ...then completed
                server.publish(v, dirty, {})
                published[0] = v
        finally:
            stop.set()

    errs = []

    def reader(t):
        rng = np.random.default_rng(t)
        first = True
        while first or not stop.is_set():
            first = False
            idx = rng.choice(rows, size=32, replace=False)
            # plain lookup: one batch, one version
            batch = server.lookup("emb0", idx)
            vals = np.unique(batch)
            assert len(vals) == 1, f"torn batch: versions {vals}"
            # pinned view: cross-table consistency under one pin
            with server.pinned() as view:
                a = np.unique(view.lookup("emb0", idx))
                b = np.unique(view.lookup("emb1", idx))
                assert len(a) == 1 and len(b) == 1
                assert a[0] == b[0] == view.step, \
                    f"cross-table tear: {a[0]} vs {b[0]} at {view.step}"

    app = threading.Thread(target=applier)
    app.start()
    try:
        hammer(reader)
    finally:
        stop.set()
        app.join()
    assert published[0] == n_versions
    # final state visible and exact
    assert server.step == n_versions
    np.testing.assert_array_equal(
        server.lookup("emb0", np.arange(rows)),
        np.full((rows, dim), n_versions, np.float32))


def test_writer_waits_for_pinned_readers_to_drain():
    server = EmbeddingServer()
    server.install({"t": np.zeros((8, 2), np.float32)}, {}, step=0)
    view = server.pinned()
    back = server.begin_apply()
    back["t"][:] = 1.0
    server.publish(1, {"t": [[0, 8]]}, {})
    # a reader still pins version 1's superseded buffers: begin_apply
    # must block until it releases
    got = []

    def writer():
        b = server.begin_apply()
        got.append(float(b["t"][0, 0]))

    th = threading.Thread(target=writer)
    th.start()
    time.sleep(0.1)
    assert th.is_alive(), "begin_apply returned while a reader held a pin"
    np.testing.assert_array_equal(view.lookup("t", np.arange(8)),
                                  np.zeros((8, 2), np.float32))
    view.release()
    th.join(timeout=5)
    assert not th.is_alive()
    assert got == [1.0], "back buffer was not resynced to the front"


# ------------------------------------------------------- manifest caching
def test_steady_state_polling_is_one_list_zero_gets():
    store = InMemoryStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        for _ in range(4):
            drv.step()
            sub.poll_once()
        c0 = store.counters.snapshot()
        misses0 = sub.cache.misses
        for _ in range(10):
            assert sub.poll_once() is False
        c1 = store.counters.snapshot()
        assert c1["get_ops"] == c0["get_ops"], \
            "idle polls must not re-read manifests"
        assert sub.cache.misses == misses0
        # one new step: exactly ONE manifest get (the new head); the rest
        # of the chain walk revalidates cached entries via size()
        chain_len = len(mf.recovery_chain(store, 4))
        hits0 = sub.cache.hits
        drv.step()
    finally:
        drv.close()
    g0 = store.counters.snapshot()["get_ops"]
    assert sub.poll_once() is True
    gets_used = store.counters.snapshot()["get_ops"] - g0
    man = mf.load(store, 5)
    payload_gets = 1 + sum(len(r.chunks) for r in man.tables.values()) \
        + len(man.dense)
    assert gets_used == payload_gets
    assert sub.cache.misses == misses0 + 1  # only the new head
    assert sub.cache.hits >= hits0 + chain_len - 1


def test_manifest_cache_revalidates_on_size_change():
    store = InMemoryStore()
    drv = Driver(store)
    try:
        drv.step()
    finally:
        drv.close()
    cache = ManifestCache(store)
    m1 = cache.chain(1)[-1]
    assert (cache.hits, cache.misses) == (0, 1)
    assert cache.chain(1)[-1] is m1
    assert (cache.hits, cache.misses) == (1, 1)
    # same step, different bytes (quarantine + rewrite): size check busts
    raw = store.get(mf.manifest_key(1))
    store.put(mf.manifest_key(1), raw + b" ")
    m2 = cache.chain(1)[-1]
    assert m2 is not m1
    assert (cache.hits, cache.misses) == (1, 2)


# ---------------------------------------------------------------- metrics
def test_prometheus_serve_section():
    store = InMemoryStore()
    drv = Driver(store)
    sub = CheckpointSubscriber(store)
    try:
        drv.step()
        drv.step()
        sub.poll_once()
    finally:
        drv.close()
    text = metrics_mod.render_prometheus({"serve": sub.metrics()})
    assert 'cnr_serve_state{state="live"} 1' in text
    assert "cnr_serve_lag_steps 0" in text
    assert "cnr_serve_applied_step 2" in text
    assert 'cnr_serve_refreshes_total{kind="full"} 1' in text
    assert "cnr_serve_refresh_bytes_total" in text
    assert 'cnr_serve_manifest_cache_total{outcome="miss"}' in text


# ------------------------------------------------------------ CLI + kill
def _write_chain(root, steps=3):
    drv = Driver(LocalFSStore(root))
    try:
        for _ in range(steps):
            drv.step()
    finally:
        drv.close()


def test_ckpt_subscribe_cli_one_shot(tmp_path, capsys):
    from repro.launch import ckpt as cli

    _write_chain(str(tmp_path), steps=3)
    assert cli.main(["subscribe", "--dir", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "serving step 3" in out


@pytest.mark.slow
def test_subscribe_process_sigkill_mid_apply_store_unharmed(tmp_path):
    """SIGKILL a follower process mid-run: the store (which it only ever
    reads) stays fully restorable and a fresh subscriber converges — the
    in-memory replica is the only casualty."""
    root = str(tmp_path)
    _write_chain(root, steps=4)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.ckpt", "subscribe",
         "--dir", root, "--follow", "--poll-s", "0.05",
         "--max-polls", "1000"],
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    time.sleep(1.0)  # mid-follow, likely mid- or post-first-apply
    proc.send_signal(signal.SIGKILL)
    proc.wait(timeout=30)
    store = LocalFSStore(root)
    assert mf.list_steps(store) == [1, 2, 3, 4]
    ref = cold_restore(store, 4)  # chain fully intact
    sub = CheckpointSubscriber(store)
    assert sub.poll_once() is True
    assert sub.applied_step == 4
    with sub.server.pinned() as v:
        for name, want in ref.tables.items():
            np.testing.assert_array_equal(
                v.lookup(name, np.arange(want.shape[0])), want)
