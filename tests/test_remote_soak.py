"""Nightly seeded flaky-store soak: many (seed x error-rate) rows of the
remote fault matrix, each driving a full 4-host sharded save AND a
faulted restore over a ``FaultyTransport``.

The push-time suite runs a small default grid (the ``slow`` marker keeps
even that out of the fast set); the nightly CI job widens it via
``CNR_SOAK_SEEDS`` — same test, more seeds, no code fork between local
and CI coverage. Every row asserts the Check-N-Run atomicity contract:
the save commits, restores byte-identically to a clean-path save, and no
torn manifest ever exists.
"""

import os

import pytest

from repro.core import CheckNRunManager
from repro.core.remote_store import FaultSpec, wrap_faulty
from tests.fault_injection import assert_no_torn_manifests
from tests.test_remote_fault_matrix import (
    assert_restores_equal,
    make_cfg,
    make_remote,
    restore_arrays,
)

SEEDS = range(100, 100 + int(os.environ.get("CNR_SOAK_SEEDS", "3")))
ERROR_RATES = (0.1, 0.2)


@pytest.mark.slow
@pytest.mark.parametrize("error_rate", ERROR_RATES)
@pytest.mark.parametrize("seed", SEEDS)
def test_soak_sharded_commit_and_restore_under_faults(tiny_snapshot, seed,
                                                      error_rate):
    snap = tiny_snapshot(step=1)
    store = make_remote()
    inj = wrap_faulty(store, FaultSpec(
        seed=seed, error_rate=error_rate, partial_put_rate=error_rate / 4,
        slow_rate=0.05, slow_s=0.001, list_lag=1))
    mgr = CheckNRunManager(store, make_cfg())
    try:
        res = mgr.save(snap, block=True).result()
        assert res.step == 1
    finally:
        mgr.close()
    assert inj.injected > 0, "soak row exercised no faults"
    assert_no_torn_manifests(store)

    # restore through a RE-seeded injector so the read path draws its own
    # fault schedule rather than replaying the write path's
    inj.spec = FaultSpec(seed=seed + 7919, error_rate=error_rate,
                         slow_rate=0.05, slow_s=0.001)
    got = restore_arrays(store)

    clean = make_remote()
    mgr2 = CheckNRunManager(clean, make_cfg())
    try:
        mgr2.save(tiny_snapshot(step=1), block=True).result()
        want = mgr2.restore()
    finally:
        mgr2.close()
    assert_restores_equal(got, want)
