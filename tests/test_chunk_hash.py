"""On-device chunk content hash (kernels/chunk_hash) vs the host oracle.

The contract under test: for every bitwidth × quant method the write path
supports, hashing the device-side packed word stream equals hashing the
serialized payload bytes with the numpy oracle — the equivalence that lets
``quant_pack`` hash on device while ``ckpt scan`` re-derives the hash from
stored bytes.
"""

import numpy as np
import pytest

from repro.core import packing
from repro.kernels.chunk_hash import chunk_hash32, chunk_hash32_device
from repro.kernels.chunk_hash.kernel import chunk_hash_pallas
from repro.kernels.chunk_hash.ops import _impl_for
from repro.kernels.chunk_hash.ref import hash_words_np


def _words(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2**32, size=n, dtype=np.uint32)


# ------------------------------------------------------------------ oracle

def test_oracle_padding_and_order_sensitivity():
    payload = b"\x01\x02\x03\x04\x05"
    # zero-padding to a whole word is part of the DEFINITION
    assert chunk_hash32(payload) == chunk_hash32(payload)  # deterministic
    assert chunk_hash32(payload) == hash_words_np(
        np.frombuffer(payload + b"\x00" * 3, dtype="<u4"))
    # order-sensitive: swapping two words changes the hash
    w = _words(64, seed=1)
    swapped = w.copy()
    swapped[[3, 40]] = swapped[[40, 3]]
    assert hash_words_np(w) != hash_words_np(swapped)
    # length-sensitive: a trailing zero word is NOT a no-op
    assert hash_words_np(w) != hash_words_np(np.append(w, np.uint32(0)))


def test_oracle_empty_payload():
    assert chunk_hash32(b"") == hash_words_np(np.zeros(0, np.uint32))


def test_block_partials_compose():
    # the index-folded terms sum associatively: any blocking reproduces
    # the oracle (the property the Pallas grid relies on)
    from repro.kernels.chunk_hash.ref import finalize, mix_terms_np
    w = _words(1000, seed=2)
    acc = 0
    for lo in range(0, 1000, 192):
        blk = w[lo:lo + 192]
        acc = (acc + int(np.sum(mix_terms_np(blk, start_index=lo),
                                dtype=np.uint64))) & 0xFFFFFFFF
    assert finalize(acc, w.size) == hash_words_np(w)


# ----------------------------------------------------------- device impls

@pytest.mark.parametrize("n", [0, 1, 5, 1023, 1024, 1025, 4096, 10_000])
def test_jnp_impl_matches_oracle(n):
    w = _words(n, seed=n)
    assert chunk_hash32_device(w, impl="jnp") == hash_words_np(w)


@pytest.mark.parametrize("n", [1, 1024, 2048 + 17])
def test_pallas_interpret_matches_oracle(n):
    w = _words(n, seed=100 + n)
    got = int(chunk_hash_pallas(np.asarray(w), n, interpret=True))
    assert got == hash_words_np(w)


def test_device_count_masks_padding():
    # padded words beyond `count` must not leak into the hash
    w = _words(600, seed=7)
    padded = np.concatenate([w, np.full(424, 0xDEADBEEF, np.uint32)])
    assert chunk_hash32_device(padded, count=600, impl="jnp") \
        == hash_words_np(w)


def test_impl_for_maps_quant_impl():
    assert _impl_for("ref") == "ref"
    assert _impl_for("interpret") == "interpret"
    assert _impl_for("jnp") == "jnp"
    assert _impl_for("unknown-future-impl") == "auto"


# ----------------------------------- payload equivalence across bit widths

@pytest.mark.parametrize("bits", list(range(1, 9)))
@pytest.mark.parametrize("method", ["adaptive", "uniform_asym"])
def test_device_hash_equals_payload_oracle(bits, method):
    """bits 1-8 × both quant methods: hash of the device word stream ==
    oracle hash of the serialized payload bytes (the manifest contract)."""
    from repro.kernels.adaptive_quant import quant_pack

    rng = np.random.default_rng(bits * 31 + (method == "adaptive"))
    x = rng.normal(size=(37, 24)).astype(np.float32)  # ragged, non-lane
    pq = quant_pack(x, bits=bits, method=method, impl="jnp")
    payload = packing.words_to_payload(np.asarray(pq.words), pq.count, bits)
    n_words = (len(payload) + 3) // 4
    got = chunk_hash32_device(pq.words, count=n_words, impl="jnp")
    assert got == chunk_hash32(payload)


@pytest.mark.parametrize("bits", [1, 4, 7])
def test_device_hash_equals_payload_oracle_interpret(bits):
    """Same equivalence through the actual Pallas kernel (interpret mode
    on CPU — the TPU codepath minus the hardware)."""
    from repro.kernels.adaptive_quant import quant_pack

    rng = np.random.default_rng(bits)
    x = rng.normal(size=(53, 16)).astype(np.float32)
    pq = quant_pack(x, bits=bits, method="adaptive", impl="jnp")
    payload = packing.words_to_payload(np.asarray(pq.words), pq.count, bits)
    n_words = (len(payload) + 3) // 4
    got = chunk_hash32_device(pq.words, count=n_words, impl="interpret")
    assert got == chunk_hash32(payload)


# ------------------------------------------------- manifest-level recording

def test_manager_records_and_verifies_hash32(tiny_snapshot):
    """End to end: saved chunks carry hash32; every recorded hash matches
    an independent oracle recomputation from the stored bytes; the config
    knob turns recording off."""
    from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore
    from repro.core import manifest as mf
    from repro.core.integrity import primary_section

    store = InMemoryStore()
    cfg = CheckpointConfig(policy="full_only", async_write=False,
                           chunk_rows=64)
    mgr = CheckNRunManager(store, cfg)
    mgr.save(tiny_snapshot(step=1), block=True).result()
    man = mf.load(store, 1)
    checked = 0
    for trec in man.tables.values():
        for ch in trec.chunks:
            assert ch.hash32 is not None
            data = store.get(ch.key)
            o, n = ch.sections[primary_section(ch)]
            assert chunk_hash32(data[o:o + n]) == ch.hash32
            checked += 1
    assert checked > 0
    mgr.close()

    store2 = InMemoryStore()
    cfg2 = CheckpointConfig(policy="full_only", async_write=False,
                            chunk_rows=64, chunk_hash=False)
    mgr2 = CheckNRunManager(store2, cfg2)
    mgr2.save(tiny_snapshot(step=1), block=True).result()
    man2 = mf.load(store2, 1)
    assert all(ch.hash32 is None for trec in man2.tables.values()
               for ch in trec.chunks)
    # and restore still round-trips without hashes
    rs = mgr2.restore()
    assert rs.step == 1
    mgr2.close()


def test_fused_and_host_pack_hashes_agree(tiny_snapshot):
    """fused_pack=True (device words hashed on device) and
    fused_pack=False (host-packed payload hashed on host) must record the
    SAME hash32 — byte-identical payloads imply identical hashes."""
    from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore
    from repro.core import manifest as mf

    hashes = {}
    for fused in (True, False):
        store = InMemoryStore()
        cfg = CheckpointConfig(policy="full_only", async_write=False,
                               chunk_rows=64, fused_pack=fused)
        mgr = CheckNRunManager(store, cfg)
        mgr.save(tiny_snapshot(step=1), block=True).result()
        man = mf.load(store, 1)
        hashes[fused] = {ch.key: ch.hash32
                         for trec in man.tables.values()
                         for ch in trec.chunks}
        mgr.close()
    assert hashes[True] == hashes[False]
    assert all(h is not None for h in hashes[True].values())
