"""Fault-injection store wrappers for crash-consistency testing.

:class:`FailingStore` wraps any ObjectStore and kills writes matching a key
predicate after N successful matching puts — simulating one host of a
sharded save dying mid-checkpoint at a chosen point (during its chunk
writes, or exactly at its part-manifest vote). Reads are never failed, so
the surviving state can always be inspected and restored.
"""

from __future__ import annotations

import threading
from typing import Callable, Iterable, Optional

from repro.core import manifest as mf
from repro.core.storage import ObjectStore


class InjectedWriteError(IOError):
    """The injected failure — a distinct type so tests can assert the crash
    path reports the root cause, not a derived error."""


def host_keys(host: int) -> Callable[[str], bool]:
    """Predicate matching every key a given simulated host writes: its chunk
    namespace and its part manifest."""
    chunk_tag = f"/host_{host:04d}/"
    part_tag = f"/host_{host:04d}.json"

    def match(key: str) -> bool:
        return chunk_tag in key or key.endswith(part_tag)

    return match


class FailingStore(ObjectStore):
    """Wraps ``inner``; the (fail_after+1)-th put whose key satisfies
    ``match`` — and every matching put thereafter — raises
    :class:`InjectedWriteError`. ``fail_after=0`` kills the host's first
    write; a large value lets the chunks land and kills the part-manifest
    vote. Thread-safe (hosts write from worker threads)."""

    def __init__(self, inner: ObjectStore,
                 match: Optional[Callable[[str], bool]] = None,
                 fail_after: Optional[int] = None) -> None:
        super().__init__()
        self.inner = inner
        self.counters = inner.counters
        self.match = match or (lambda key: True)
        self.fail_after = fail_after  # None → armed off
        self.matching_puts = 0
        self.failed_keys: list = []
        self._lock = threading.Lock()

    def arm(self, match: Callable[[str], bool], fail_after: int) -> None:
        with self._lock:
            self.match = match
            self.fail_after = fail_after
            self.matching_puts = 0
            self.failed_keys = []

    def disarm(self) -> None:
        with self._lock:
            self.fail_after = None

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            if self.fail_after is not None and self.match(key):
                if self.matching_puts >= self.fail_after:
                    self.failed_keys.append(key)
                    raise InjectedWriteError(f"injected write failure: {key}")
                self.matching_puts += 1
        self.inner.put(key, data)

    def get(self, key: str) -> bytes:
        return self.inner.get(key)

    def delete(self, key: str) -> None:
        self.inner.delete(key)

    def list(self, prefix: str = "") -> Iterable[str]:
        return self.inner.list(prefix)

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def size(self, key: str) -> int:
        return self.inner.size(key)


def assert_no_torn_manifests(store: ObjectStore) -> None:
    """The two-phase commit invariant: every committed sharded manifest has
    ALL its part manifests durable and every referenced chunk present."""
    for step in mf.list_steps(store):
        man = mf.load(store, step)
        if man.shards is None:
            continue
        n = man.shards["num_hosts"]
        hosts = mf.list_part_hosts(store, step)
        assert hosts == list(range(n)), (
            f"committed manifest {step} missing parts: have {hosts}, "
            f"need {n}")
        for rec in man.tables.values():
            for ch in rec.chunks:
                assert store.exists(ch.key), f"missing chunk {ch.key}"
        for drec in man.dense.values():
            assert store.exists(drec.key), f"missing dense {drec.key}"
