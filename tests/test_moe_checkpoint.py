"""MoE expert-block incremental checkpointing (the beyond-paper extension:
expert-granular touched units with expansion > 1, plus 2-D per-row optimizer
aux) must round-trip bit-exactly."""

import jax
import numpy as np

from repro.configs import get_cell
from repro.core import CheckpointConfig, InMemoryStore
from repro.train.loop import Trainer, TrainerConfig


def _flat(tree):
    return {jax.tree_util.keystr(p): np.asarray(jax.device_get(l))
            for p, l in jax.tree_util.tree_flatten_with_path(tree)[0]}


def test_moe_expert_restore_bit_exact():
    b = get_cell("olmoe-1b-7b", "train_4k", reduced=True)
    assert any(s.expansion > 1 for s in b.tracked.values())  # expert specs
    store = InMemoryStore()
    cfg = CheckpointConfig(interval_batches=2, policy="one_shot", quant=None,
                           async_write=False)
    t = Trainer(b, store, cfg, TrainerConfig(total_steps=4,
                                             use_reader_tier=False))
    t.init_or_restore()
    t.run(4)
    ref_p, ref_o = _flat(t.state.params), _flat(t.state.opt_state)
    t.close()

    t2 = Trainer(b, store, cfg, TrainerConfig(total_steps=4,
                                              use_reader_tier=False))
    assert t2.init_or_restore() == 4
    got_p, got_o = _flat(t2.state.params), _flat(t2.state.opt_state)
    for k in ref_p:
        np.testing.assert_array_equal(ref_p[k], got_p[k], err_msg=k)
    for k in ref_o:
        np.testing.assert_array_equal(ref_o[k], got_o[k], err_msg=k)
    t2.close()


def test_moe_increment_smaller_when_few_experts_touched():
    """With top-k routing, an interval that touches a subset of experts
    yields an increment smaller than a full expert dump."""
    import dataclasses

    from repro.core import Snapshot, CheckNRunManager
    rng = np.random.default_rng(0)
    L, E, d, F = 2, 8, 16, 32
    w = rng.normal(size=(L * E * d, F)).astype(np.float32)
    store = InMemoryStore()
    mgr = CheckNRunManager(store, CheckpointConfig(policy="one_shot",
                                                   quant=None,
                                                   async_write=False))
    full_mask = np.ones(L * E * d, dtype=bool)
    r1 = mgr.save(Snapshot(step=1, tables={"w_up": w.copy()},
                           row_state={"w_up": {}},
                           touched={"w_up": full_mask}, dense={}, extra={})).result()
    # only 2 of 16 (layer, expert) units touched
    partial = np.zeros(L * E * d, dtype=bool)
    partial[:2 * d] = True
    w[:2 * d] += 0.1
    r2 = mgr.save(Snapshot(step=2, tables={"w_up": w.copy()},
                           row_state={"w_up": {}},
                           touched={"w_up": partial}, dense={}, extra={})).result()
    assert r2.kind == "incremental"
    assert r2.nbytes < 0.2 * r1.nbytes
    rs = mgr.restore()
    np.testing.assert_array_equal(rs.tables["w_up"], w)
