"""Garbage collection under the sharded layout, plus a property test that
random save/crash/restore interleavings never lose a committed step.

Orphaned per-host part manifests and chunk blobs come from two sources:
crashed sharded saves (some hosts voted, commit never happened) and
cancelled single-host saves (§3.3 straggler mitigation). Both must be
reclaimed by ``manifest.gc_aborted`` — which the manager runs after every
committed save — without ever touching a committed checkpoint's blobs.
"""

import dataclasses

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore
from repro.core import manifest as mf
from tests.fault_injection import (
    FailingStore,
    InjectedWriteError,
    assert_no_torn_manifests,
    host_keys,
)

NUM_HOSTS = 3


def make_mgr(store, **overrides):
    cfg = dict(policy="one_shot", quant=None, async_write=False,
               chunk_rows=64, keep_latest=10, num_hosts=NUM_HOSTS)
    cfg.update(overrides)
    return CheckNRunManager(store, CheckpointConfig(**cfg))


def crash_save(store, mgr, snap, victim, fail_after):
    store.arm(host_keys(victim), fail_after)
    with pytest.raises(InjectedWriteError):
        mgr.save(snap).result()
    store.disarm()


def test_gc_reclaims_aborted_save_only(tiny_snapshot):
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    mgr.save(tiny_snapshot(step=1)).result()
    committed_keys = set(store.list("chunks/")) | set(store.list("parts/"))

    crash_save(store, mgr, tiny_snapshot(step=2, seed=2), victim=1,
               fail_after=1)
    assert mf.aborted_steps(store) == [2]
    orphans = (set(store.list("chunks/")) | set(store.list("parts/"))) \
        - committed_keys
    assert orphans  # the crash left debris (host chunks and/or votes)

    # the fence protects step 2 while it is newer than the last commit —
    # from the store alone it is indistinguishable from an in-flight save
    assert mf.gc_aborted(store) == {}
    # the operator override reclaims it (CLI gc-aborted --all)
    assert mf.gc_aborted(store, fence=None) == {2: len(orphans)}
    # committed checkpoint untouched, orphans gone
    assert set(store.list("chunks/")) | set(store.list("parts/")) \
        == committed_keys
    assert mf.aborted_steps(store) == []
    np.testing.assert_array_equal(
        mgr.restore().tables["emb0"], tiny_snapshot(step=1).tables["emb0"])
    mgr.close()


def test_gc_fence_lifts_once_newer_step_commits(tiny_snapshot):
    """Debris older than the newest committed manifest cannot be an
    in-flight save (steps are monotone) — the default sweep reclaims it."""
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    mgr.save(tiny_snapshot(step=1)).result()
    crash_save(store, mgr, tiny_snapshot(step=2, seed=2), victim=1,
               fail_after=1)
    # the manager's own post-commit pass (targeted gc_steps) reclaims the
    # abort it witnessed when step 3 commits
    mgr.save(tiny_snapshot(step=3, seed=3)).result()
    assert mf.aborted_steps(store) == []
    assert_no_torn_manifests(store)
    # and a foreign sweeper (fresh process / CLI) is equally safe now:
    # nothing left, nothing live touched
    assert mf.gc_aborted(store) == {}
    assert sorted(mf.list_steps(store)) == [1, 3]
    mgr.close()


def test_gc_exclude_steps_protects_in_flight(tiny_snapshot):
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    crash_save(store, mgr, tiny_snapshot(step=5), victim=0, fail_after=2)
    assert mf.aborted_steps(store) == [5]
    # default fence: with no committed manifest at all, every step could be
    # an in-flight save — the sweep must not touch anything
    assert mf.gc_aborted(store) == {}
    # explicit exclusion protects even under the operator override
    assert mf.gc_aborted(store, exclude_steps=[5], fence=None) == {}
    assert mf.aborted_steps(store) == [5]  # protected
    assert mf.gc_aborted(store, fence=None)[5] > 0
    mgr.close()


class _CommitDuringSweepStore(InMemoryStore):
    """Commits ``step``'s manifest the first time the chunk namespace is
    listed — the window between a GC sweep's listing and its deletions,
    where a racing last-voter commit can land."""

    def __init__(self, step: int) -> None:
        super().__init__()
        self.commit_step = step
        self.armed = False

    def list(self, prefix: str = ""):
        keys = super().list(prefix)
        if self.armed and prefix.startswith(mf.CHUNK_PREFIX):
            self.armed = False
            super().put(mf.manifest_key(self.commit_step), b"{}")
        return keys


def test_gc_aborted_skips_step_that_commits_mid_sweep():
    """check-then-delete race regression: a step that commits between the
    sweep's namespace listing and its deletion batch must keep every blob
    (any host can commit concurrently now)."""
    store = _CommitDuringSweepStore(step=2)
    store.put(mf.manifest_key(3), b"{}")       # fence: latest committed = 3
    debris = [f"{mf.chunk_prefix(2)}emb0/000000.bin", mf.part_key(2, 0)]
    for k in debris:
        store.put(k, b"blob")
    store.armed = True
    assert mf.gc_aborted(store) == {}          # re-check saw the commit
    for k in debris:
        assert store.exists(k), f"live blob {k} was reclaimed"


class _CommitOnVoteDeleteStore(InMemoryStore):
    """Commits ``step``'s manifest the instant its first vote is deleted —
    modelling a committer that finished collecting votes BEFORE the sweep
    started and lands its manifest put mid-batch."""

    def __init__(self, step: int) -> None:
        super().__init__()
        self.commit_step = step
        self.armed = False

    def delete(self, key: str) -> None:
        super().delete(key)
        if self.armed and key.startswith(mf.PART_PREFIX):
            self.armed = False
            super().put(mf.manifest_key(self.commit_step), b"{}")


def test_gc_spares_chunks_when_commit_lands_mid_batch():
    """A committer already past its own collect can commit between the
    sweep's re-check and its deletions. Votes are deleted first and the
    chunk sub-batch re-checks once more — so the committed manifest keeps
    every chunk blob it references (restore never reads the votes)."""
    store = _CommitOnVoteDeleteStore(step=2)
    store.put(mf.manifest_key(3), b"{}")       # fence: latest committed = 3
    chunk = f"{mf.chunk_prefix(2)}emb0/000000.bin"
    store.put(chunk, b"blob")
    store.put(mf.part_key(2, 0), b"{}")
    store.armed = True
    mf.gc_aborted(store)
    assert store.exists(chunk), "chunk of a just-committed step reclaimed"
    assert store.exists(mf.manifest_key(2))


def test_gc_steps_skips_step_that_commits_mid_sweep():
    store = _CommitDuringSweepStore(step=2)
    debris = [f"{mf.chunk_prefix(2)}emb0/000000.bin", mf.part_key(2, 0)]
    for k in debris:
        store.put(k, b"blob")
    store.armed = True
    assert mf.gc_steps(store, [2]) == {}
    for k in debris:
        assert store.exists(k), f"live blob {k} was reclaimed"


def test_manager_gcs_orphans_after_next_commit(tiny_snapshot):
    """The manager's post-commit hook reclaims earlier aborted saves — no
    operator action needed on the happy path."""
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    mgr.save(tiny_snapshot(step=1)).result()
    crash_save(store, mgr, tiny_snapshot(step=2, seed=2), victim=2,
               fail_after=0)
    assert mf.aborted_steps(store) == [2]
    mgr.save(tiny_snapshot(step=3, seed=3)).result()
    assert mf.aborted_steps(store) == []
    assert_no_torn_manifests(store)
    mgr.close()


def test_gc_reclaims_cancelled_single_host_save(tiny_snapshot):
    """Cancelled (§3.3) single-host saves also leave chunk debris; the
    shared GC path reclaims it the same way."""
    store = InMemoryStore()
    mgr = make_mgr(store, num_hosts=1)
    mgr.save(tiny_snapshot(step=1)).result()
    # fake a cancelled save's leftovers: chunks, no manifest
    store.put(f"{mf.chunk_prefix(2)}emb0/000000.bin", b"partial")
    assert mf.aborted_steps(store) == [2]
    assert mf.gc_aborted(store) == {}  # fenced: newer than last commit
    mgr.save(tiny_snapshot(step=3, seed=3)).result()
    # older than the fence now; this manager never aborted step 2 itself,
    # so the debris waits for a namespace sweep (fresh process or CLI)
    assert mf.gc_aborted(store) == {2: 1}
    assert mf.list_steps(store) == [1, 3]
    mgr.close()


def test_retention_deletes_parts_of_dropped_steps(tiny_snapshot):
    store = InMemoryStore()
    mgr = make_mgr(store, policy="full_only", keep_latest=1)
    for step in (1, 2, 3):
        mgr.save(tiny_snapshot(step=step, seed=step)).result()
    assert mf.list_steps(store) == [3]
    leftover = [k for k in store.list("parts/")
                if not k.startswith(mf.part_prefix(3))]
    assert leftover == []
    assert len(mf.list_part_hosts(store, 3)) == NUM_HOSTS
    mgr.close()


# --------------------------------------------------------------------------
# property: random save/crash/restore interleavings never lose a committed
# step (deterministic sweep always runs; hypothesis widens the search when
# installed, honoring the conftest stub otherwise)
# --------------------------------------------------------------------------


def _run_interleaving(seed: int, n_events: int = 10) -> None:
    rng = np.random.default_rng(seed)
    inner = InMemoryStore()
    store = FailingStore(inner)
    num_hosts = int(rng.integers(2, 5))
    mgr = make_mgr(store, num_hosts=num_hosts,
                   policy=str(rng.choice(["one_shot", "consecutive",
                                          "intermittent", "full_only"])))
    R, D = 150, 4
    table = rng.normal(size=(R, D)).astype(np.float32)
    committed = {}   # step -> table bytes at commit
    step = 0
    from repro.core.snapshot import Snapshot

    for _ in range(n_events):
        event = rng.choice(["save", "crash_save", "restore"])
        if event in ("save", "crash_save"):
            step += 1
            idx = rng.choice(R, size=int(rng.integers(1, 40)), replace=False)
            table[idx] += rng.normal(size=(len(idx), D)).astype(np.float32)
            mask = np.zeros(R, bool)
            mask[idx] = True
            snap = Snapshot(step=step, tables={"T": table.copy()},
                            row_state={"T": {}}, touched={"T": mask},
                            dense={}, extra={})
            if event == "save":
                mgr.save(snap).result()
                committed[step] = table.copy()
            else:
                # arm an injection at a random point; with sparse touches the
                # victim may finish before it fires, in which case the save
                # legitimately committed — both outcomes must stay consistent
                store.arm(host_keys(int(rng.integers(0, num_hosts))),
                          int(rng.integers(0, 4)))
                try:
                    mgr.save(snap).result()
                    committed[step] = table.copy()
                except InjectedWriteError:
                    pass
                store.disarm()
        else:
            if not committed:
                continue
            # a fresh manager, as after a real failure (§3.1 recovery)
            rs = CheckNRunManager(store, mgr.config).restore()
            assert rs.step == max(committed)
            np.testing.assert_array_equal(rs.tables["T"],
                                          committed[rs.step])
        assert_no_torn_manifests(store)
        latest = mf.latest_step(store)
        assert latest == (max(committed) if committed else None)
    mgr.close()


@pytest.mark.parametrize("seed", range(8))
def test_random_interleavings_never_lose_committed_step(seed):
    _run_interleaving(seed)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1))
def test_random_interleavings_property(seed):
    _run_interleaving(seed, n_events=8)
