"""Stateful property test: under ANY sequence of (touch-pattern, policy,
quantization, cancellation) events, restore() must reconstruct the live
table exactly (fp32) or within the quantization step (quantized)."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    CheckNRunManager,
    CheckpointConfig,
    InMemoryStore,
    PAPER_DEFAULTS,
    Snapshot,
)

ROWS, DIM = 300, 8


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    policy=st.sampled_from(["one_shot", "consecutive", "intermittent", "full_only"]),
    bits=st.sampled_from([0, 4, 8]),
    n_intervals=st.integers(2, 6),
    keep_latest=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_restore_always_matches_live(policy, bits, n_intervals, keep_latest, seed):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(ROWS, DIM)).astype(np.float32)
    acc = np.abs(rng.normal(size=ROWS)).astype(np.float32)
    quant = PAPER_DEFAULTS[bits] if bits else None
    mgr = CheckNRunManager(InMemoryStore(), CheckpointConfig(
        policy=policy, quant=quant, async_write=False,
        keep_latest=keep_latest, chunk_rows=64))
    for step in range(1, n_intervals + 1):
        k = int(rng.integers(1, ROWS // 2))
        idx = rng.choice(ROWS, size=k, replace=False)
        table[idx] += rng.normal(size=(k, DIM)).astype(np.float32)
        acc[idx] += 0.1
        t = np.zeros(ROWS, bool)
        t[idx] = True
        mgr.save(Snapshot(step=step, tables={"T": table.copy()},
                          row_state={"T": {"acc": acc.copy()}},
                          touched={"T": t}, dense={}, extra={})).result()
    rs = mgr.restore()
    assert rs.step == n_intervals
    np.testing.assert_array_equal(rs.row_state["T"]["acc"], acc)
    if bits == 0:
        np.testing.assert_array_equal(rs.tables["T"], table)
    else:
        # per-row error bounded by that row's quantization step (+fp16 meta)
        step_sz = (table.max(1) - table.min(1)) / (2 ** bits - 1)
        err = np.abs(rs.tables["T"] - table).max(axis=1)
        assert np.all(err <= step_sz * 1.01 + 2e-2)
    mgr.close()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 8))
def test_touched_union_is_complete(seed, n):
    """Rows touched in ANY interval since baseline appear in the cumulative
    increment — no update may be lost (one-shot policy)."""
    rng = np.random.default_rng(seed)
    table = np.zeros((ROWS, DIM), np.float32)
    mgr = CheckNRunManager(InMemoryStore(), CheckpointConfig(
        policy="one_shot", quant=None, async_write=False, keep_latest=5))
    all_touched = np.zeros(ROWS, bool)
    for step in range(1, n + 1):
        idx = rng.choice(ROWS, size=10, replace=False)
        table[idx] = step
        all_touched[idx] = True
        t = np.zeros(ROWS, bool)
        t[idx] = True
        mgr.save(Snapshot(step=step, tables={"T": table.copy()},
                          row_state={"T": {}}, touched={"T": t},
                          dense={}, extra={})).result()
    rs = mgr.restore()
    np.testing.assert_array_equal(rs.tables["T"], table)
    mgr.close()


def _check_aux8_roundtrip(base, range_exp, constant, seed):
    """aux8 encode/decode over degenerate ranges: a constant chunk (hi==lo)
    must round-trip EXACTLY, and a near-zero-range chunk (spreads down to
    float32 subnormals, where a float32 `(hi-lo)/255` underflows to 0) must
    stay within HALF a quantization step — nearest-code rounding."""
    rng = np.random.default_rng(seed)
    if constant:
        acc = np.full(ROWS, base, np.float32)
    else:
        spread = np.float32(10.0) ** np.float32(range_exp)
        acc = (np.float32(base)
               + rng.uniform(0, 1, ROWS).astype(np.float32) * spread)
    table = rng.normal(size=(ROWS, DIM)).astype(np.float32)
    mgr = CheckNRunManager(InMemoryStore(), CheckpointConfig(
        policy="full_only", quant=None, async_write=False,
        chunk_rows=64, aux_bits=8))
    mgr.save(Snapshot(step=1, tables={"T": table},
                      row_state={"T": {"acc": acc.copy()}},
                      touched={}, dense={}, extra={})).result()
    rs = mgr.restore()
    got = rs.row_state["T"]["acc"]
    assert got.dtype == np.float32
    if constant:
        np.testing.assert_array_equal(got, acc)
    else:
        # per-chunk bound: |err| <= half that chunk's (hi - lo) / 255 step
        for lo_r in range(0, ROWS, 64):
            blk, gblk = acc[lo_r:lo_r + 64], got[lo_r:lo_r + 64]
            span = float(blk.max()) - float(blk.min())
            np.testing.assert_allclose(gblk, blk, atol=max(span / 255, 0)
                                       * 0.501 + 1e-38, rtol=0)
    mgr.close()


@settings(max_examples=25, deadline=None)
@given(
    base=st.floats(-1e6, 1e6, allow_nan=False, width=32),
    range_exp=st.integers(-45, 2),  # 1e-45 (subnormal) .. 1e2 spreads
    constant=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_aux8_degenerate_range_roundtrip(base, range_exp, constant, seed):
    _check_aux8_roundtrip(base, range_exp, constant, seed)


@pytest.mark.parametrize("base,range_exp,constant", [
    (0.0, -45, False),      # float32 subnormal span around zero
    (1.0, -45, False),      # span vanishes next to the base magnitude
    (3.14, -40, False),
    (-1e6, -30, False),
    (0.0, -20, False),
    (-17.0, 2, False),      # sane span: sanity-check the bound itself
    (123.456, 0, True),     # hi == lo, non-zero constant
    (0.0, 0, True),         # hi == lo == 0
])
def test_aux8_degenerate_range_examples(base, range_exp, constant):
    """Deterministic pin of the hypothesis cases above so the regression
    runs even where hypothesis is stubbed out."""
    _check_aux8_roundtrip(base, range_exp, constant, seed=7)
