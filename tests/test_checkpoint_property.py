"""Stateful property test: under ANY sequence of (touch-pattern, policy,
quantization, cancellation) events, restore() must reconstruct the live
table exactly (fp32) or within the quantization step (quantized)."""

import numpy as np
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (
    CheckNRunManager,
    CheckpointConfig,
    InMemoryStore,
    PAPER_DEFAULTS,
    Snapshot,
)

ROWS, DIM = 300, 8


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    policy=st.sampled_from(["one_shot", "consecutive", "intermittent", "full_only"]),
    bits=st.sampled_from([0, 4, 8]),
    n_intervals=st.integers(2, 6),
    keep_latest=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_restore_always_matches_live(policy, bits, n_intervals, keep_latest, seed):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(ROWS, DIM)).astype(np.float32)
    acc = np.abs(rng.normal(size=ROWS)).astype(np.float32)
    quant = PAPER_DEFAULTS[bits] if bits else None
    mgr = CheckNRunManager(InMemoryStore(), CheckpointConfig(
        policy=policy, quant=quant, async_write=False,
        keep_latest=keep_latest, chunk_rows=64))
    for step in range(1, n_intervals + 1):
        k = int(rng.integers(1, ROWS // 2))
        idx = rng.choice(ROWS, size=k, replace=False)
        table[idx] += rng.normal(size=(k, DIM)).astype(np.float32)
        acc[idx] += 0.1
        t = np.zeros(ROWS, bool)
        t[idx] = True
        mgr.save(Snapshot(step=step, tables={"T": table.copy()},
                          row_state={"T": {"acc": acc.copy()}},
                          touched={"T": t}, dense={}, extra={})).result()
    rs = mgr.restore()
    assert rs.step == n_intervals
    np.testing.assert_array_equal(rs.row_state["T"]["acc"], acc)
    if bits == 0:
        np.testing.assert_array_equal(rs.tables["T"], table)
    else:
        # per-row error bounded by that row's quantization step (+fp16 meta)
        step_sz = (table.max(1) - table.min(1)) / (2 ** bits - 1)
        err = np.abs(rs.tables["T"] - table).max(axis=1)
        assert np.all(err <= step_sz * 1.01 + 2e-2)
    mgr.close()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 8))
def test_touched_union_is_complete(seed, n):
    """Rows touched in ANY interval since baseline appear in the cumulative
    increment — no update may be lost (one-shot policy)."""
    rng = np.random.default_rng(seed)
    table = np.zeros((ROWS, DIM), np.float32)
    mgr = CheckNRunManager(InMemoryStore(), CheckpointConfig(
        policy="one_shot", quant=None, async_write=False, keep_latest=5))
    all_touched = np.zeros(ROWS, bool)
    for step in range(1, n + 1):
        idx = rng.choice(ROWS, size=10, replace=False)
        table[idx] = step
        all_touched[idx] = True
        t = np.zeros(ROWS, bool)
        t[idx] = True
        mgr.save(Snapshot(step=step, tables={"T": table.copy()},
                          row_state={"T": {}}, touched={"T": t},
                          dense={}, extra={})).result()
    rs = mgr.restore()
    np.testing.assert_array_equal(rs.tables["T"], table)
    mgr.close()
