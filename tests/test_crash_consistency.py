"""Crash-consistency suite for the sharded multi-host write engine.

The contract under test (paper §3.4 + docs/sharded_writers.md): killing ANY
one host at ANY point during a sharded save leaves the store in a state
where ``restore()`` returns the previous committed checkpoint
byte-identically, and no global manifest ever exists with missing parts.
A completed sharded save must restore byte-identically to the single-host
path on the same snapshot.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    CheckNRunManager,
    CheckpointConfig,
    CommitCoordinator,
    InMemoryStore,
    PAPER_DEFAULTS,
    ShardCommitError,
)
from repro.core import manifest as mf
from tests.fault_injection import (
    FailingStore,
    InjectedWriteError,
    assert_no_torn_manifests,
    host_keys,
)

NUM_HOSTS = 4


def make_mgr(store, **overrides):
    cfg = dict(policy="one_shot", quant=None, async_write=False,
               chunk_rows=64, keep_latest=10, num_hosts=NUM_HOSTS)
    cfg.update(overrides)
    return CheckNRunManager(store, CheckpointConfig(**cfg))


def touch(snap, rng, k=40):
    """Mutate ~k rows per table in-place and set the touched masks."""
    for name, tab in snap.tables.items():
        idx = rng.choice(tab.shape[0], size=k, replace=False)
        tab[idx] += rng.normal(size=(k, tab.shape[1])).astype(np.float32)
        mask = np.zeros(tab.shape[0], bool)
        mask[idx] = True
        snap.touched[name] = mask
    return snap


def capture(rs):
    return ({n: t.copy() for n, t in rs.tables.items()},
            {n: {a: v.copy() for a, v in d.items()}
             for n, d in rs.row_state.items()},
            {n: v.copy() for n, v in rs.dense.items()})


def assert_state_equal(rs, ref):
    tables, row_state, dense = ref
    assert set(rs.tables) == set(tables)
    for n in tables:
        np.testing.assert_array_equal(rs.tables[n], tables[n])
        for a in row_state[n]:
            np.testing.assert_array_equal(rs.row_state[n][a], row_state[n][a])
    for n in dense:
        np.testing.assert_array_equal(rs.dense[n], dense[n])


# --------------------------------------------------------------------------
# acceptance: completed sharded save ≡ single-host save, byte-identical
# --------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [0, 4])
def test_sharded_restore_byte_identical_to_single_host(tiny_snapshot, bits):
    quant = PAPER_DEFAULTS[bits] if bits else None
    snap = tiny_snapshot(step=1, tables=3)
    s1, s4 = InMemoryStore(), InMemoryStore()
    make_mgr(s1, num_hosts=1, quant=quant).save(snap).result()
    make_mgr(s4, quant=quant).save(snap).result()
    r1 = make_mgr(s1, num_hosts=1, quant=quant).restore()
    r4 = make_mgr(s4, quant=quant).restore()
    assert_state_equal(r4, capture(r1))
    man = mf.load(s4, 1)
    assert man.shards["num_hosts"] == NUM_HOSTS
    assert_no_torn_manifests(s4)


def test_restore_part_matches_full_restore_slice(tiny_snapshot):
    snap = tiny_snapshot(step=1)
    store = InMemoryStore()
    mgr = make_mgr(store)
    mgr.save(snap).result()
    full = mgr.restore()
    for host in range(NUM_HOSTS):
        part = mgr.restore_part(host)
        for name in snap.tables:
            lo, hi = part.extra["shard"]["row_range"][name]
            np.testing.assert_array_equal(part.tables[name],
                                          full.tables[name][lo:hi])
            np.testing.assert_array_equal(part.row_state[name]["acc"],
                                          full.row_state[name]["acc"][lo:hi])


# --------------------------------------------------------------------------
# crash matrix: kill any host at any injected point → previous checkpoint
# --------------------------------------------------------------------------


@pytest.mark.parametrize("victim", range(NUM_HOSTS))
@pytest.mark.parametrize("fail_after", [0, 1, 3])
def test_killed_host_leaves_previous_checkpoint(tiny_snapshot, victim,
                                                fail_after):
    """Host ``victim`` dies after ``fail_after`` of its puts (chunk writes
    or, once they are exhausted, the part-manifest vote)."""
    rng = np.random.default_rng(victim * 10 + fail_after)
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    snap = tiny_snapshot(step=1)
    mgr.save(snap).result()
    ref = capture(mgr.restore())

    touch(snap, rng)
    snap2 = dataclasses.replace(snap, step=2)
    store.arm(host_keys(victim), fail_after)
    with pytest.raises(InjectedWriteError):
        mgr.save(snap2).result()
    store.disarm()

    # previous checkpoint is still the latest valid one, byte-identical
    # (restored through a fresh manager, as a restarted job would)
    assert mf.latest_step(store) == 1
    assert_state_equal(CheckNRunManager(store, mgr.config).restore(), ref)
    assert_no_torn_manifests(store)

    # the job recovers: rows from the aborted interval roll into the next
    # committed checkpoint, and the orphaned debris is reclaimed post-commit
    snap3 = dataclasses.replace(snap2, step=3)
    mgr.save(snap3).result()
    assert mf.latest_step(store) == 3
    rs = mgr.restore()
    for name, tab in snap3.tables.items():
        np.testing.assert_array_equal(rs.tables[name], tab)
    assert mf.aborted_steps(store) == []
    assert_no_torn_manifests(store)
    mgr.close()


def test_vote_killed_exactly_at_part_manifest(tiny_snapshot):
    """All the victim's chunks land; only its part-manifest vote fails —
    the torn-est possible state short of a committed manifest."""
    victim = 2
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    snap = tiny_snapshot(step=1)
    mgr.save(snap).result()
    ref = capture(mgr.restore())

    snap2 = dataclasses.replace(
        touch(snap, np.random.default_rng(7)), step=2)
    store.arm(lambda k: k == mf.part_key(2, victim), 0)
    with pytest.raises(InjectedWriteError):
        mgr.save(snap2).result()
    store.disarm()

    # victim's chunks are durable but its vote is not → no commit
    assert store.list(mf.chunk_host_prefix(2, victim)) != []
    assert not store.exists(mf.part_key(2, victim))
    assert mf.latest_step(store) == 1
    assert_state_equal(mgr.restore(), ref)
    assert_no_torn_manifests(store)
    mgr.close()


def test_stale_vote_from_prior_attempt_cannot_commit(tiny_snapshot):
    """Retry of the SAME step after an aborted attempt: the victim host's
    leftover phase-1 vote (matching step/host/num_hosts stamps and chunk
    sizes) must not be laundered into a commit when the victim dies again
    before re-voting."""
    rng = np.random.default_rng(11)
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    snap = tiny_snapshot(step=1)
    mgr.save(snap).result()
    ref = capture(mgr.restore())

    # attempt 1 at step 2: host 3 dies exactly at its vote → hosts 0-2
    # leave durable stale votes for step 2. The fail-fast cancel races
    # the surviving hosts' votes, so repeat the aborted attempt (a
    # same-step retry purges leftovers first) until host 1's stale vote
    # is durable — the precondition the laundering check below needs.
    snap2 = dataclasses.replace(touch(snap, rng), step=2)
    for _ in range(20):
        store.arm(lambda k: k == mf.part_key(2, 3), 0)
        with pytest.raises(InjectedWriteError):
            mgr.save(snap2).result()
        store.disarm()
        if store.exists(mf.part_key(2, 1)):
            break
    voted = mf.list_part_hosts(store, 2)
    assert 1 in voted and 3 not in voted

    # attempt 2 at the same step with DIFFERENT data: host 1 dies before
    # writing anything, so only its stale attempt-1 vote could vouch for it
    snap2b = dataclasses.replace(touch(snap2, rng), step=2)
    store.arm(host_keys(1), 0)
    with pytest.raises(InjectedWriteError):
        mgr.save(snap2b).result()
    store.disarm()

    # no commit, no attempt-mixing: step 1 still restores byte-identically
    assert mf.latest_step(store) == 1
    assert not store.exists(mf.part_key(2, 1))  # stale vote was purged
    assert_state_equal(CheckNRunManager(store, mgr.config).restore(), ref)
    assert_no_torn_manifests(store)
    mgr.close()


def test_sharded_resave_of_committed_step_refused(tiny_snapshot):
    """Overwriting a committed step in place would let a crash tear a
    checkpoint that claims to be valid — the sharded path refuses, and the
    committed state (manifest, votes, chunks) stays untouched."""
    store = InMemoryStore()
    mgr = make_mgr(store)
    snap = tiny_snapshot(step=1)
    mgr.save(snap).result()
    before = {k: store.get(k) for k in store.list("")}
    with pytest.raises(ValueError, match="already has a committed"):
        mgr.save(dataclasses.replace(
            touch(snap, np.random.default_rng(5)), step=1)).result()
    assert {k: store.get(k) for k in store.list("")} == before
    mgr.close()


def test_coordinator_refuses_missing_part(tiny_snapshot):
    """Phase 2 in isolation: with only 3 of 4 votes durable, commit raises
    and writes nothing."""
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store)
    snap = tiny_snapshot(step=1)
    store.arm(lambda k: k == mf.part_key(1, 3), 0)
    with pytest.raises(InjectedWriteError):
        mgr.save(snap).result()
    store.disarm()
    # host 3's vote must be absent; hosts 0-2 voted UNLESS the fail-fast
    # cancel pre-empted them first (the cancel event races their votes —
    # any subset of {0,1,2} is a legal surviving state)
    voted = mf.list_part_hosts(store, 1)
    assert 3 not in voted
    assert set(voted) <= {0, 1, 2}

    coord = CommitCoordinator(store, NUM_HOSTS)
    with pytest.raises(ShardCommitError, match="missing"):
        coord.commit(1, kind="full", base_step=1, prev_step=None, quant=None,
                     policy={"name": "one_shot"}, extra={})
    assert mf.list_steps(store) == []
    mgr.close()


def test_coordinator_refuses_missing_chunk(tiny_snapshot):
    """A vote whose chunks were tampered away must not commit (verify_chunks
    guard)."""
    store = InMemoryStore()
    mgr = make_mgr(store)
    mgr.save(tiny_snapshot(step=1)).result()
    # sabotage: delete one durable chunk of host 1, keep its vote — and
    # drop the committed manifest so phase 2 actually re-runs (try_commit
    # is idempotent: an existing manifest short-circuits it)
    victim_chunks = list(store.list(mf.chunk_host_prefix(1, 1)))
    store.delete(victim_chunks[0])
    store.delete(mf.manifest_key(1))
    coord = CommitCoordinator(store, NUM_HOSTS)
    with pytest.raises(ShardCommitError, match="not durable"):
        coord.commit(1, kind="full", base_step=1, prev_step=None, quant=None,
                     policy={"name": "one_shot"}, extra={})
    mgr.close()


def test_incremental_chain_survives_crashes(tiny_snapshot):
    """full → crash → increment → crash → increment: every committed step
    restores the live table exactly; crashes never corrupt the chain."""
    rng = np.random.default_rng(3)
    inner = InMemoryStore()
    store = FailingStore(inner)
    mgr = make_mgr(store, policy="consecutive")
    snap = tiny_snapshot(step=1)
    mgr.save(snap).result()

    step = 1
    for round_ in range(3):
        # crashed attempt (victim rotates)
        step += 1
        snap = dataclasses.replace(touch(snap, rng), step=step)
        store.arm(host_keys(round_ % NUM_HOSTS), round_)
        with pytest.raises(InjectedWriteError):
            mgr.save(snap).result()
        store.disarm()
        # committed attempt rolls the crashed interval's rows forward
        step += 1
        snap = dataclasses.replace(touch(snap, rng), step=step)
        mgr.save(snap).result()
        rs = mgr.restore()
        for name, tab in snap.tables.items():
            np.testing.assert_array_equal(rs.tables[name], tab)
        assert_no_torn_manifests(store)
    mgr.close()
