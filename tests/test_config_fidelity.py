"""Config fidelity: full (non-reduced) configs must match the published
architecture numbers — parameter counts within tolerance of the models'
names, and the exact structural hyper-parameters from the assignment."""

import pytest

from repro.configs import _module


@pytest.mark.parametrize("arch,total_b,active_b,tol", [
    ("olmoe-1b-7b", 6.9e9, 1.3e9, 0.25),
    ("dbrx-132b", 132e9, 36e9, 0.15),
    ("nemotron-4-15b", 15e9, 15e9, 0.25),
    ("qwen2-0.5b", 0.5e9, 0.5e9, 0.35),
    ("minicpm3-4b", 4e9, 4e9, 0.30),
])
def test_lm_param_counts(arch, total_b, active_b, tol):
    cfg = _module(arch).make_config(reduced=False)
    assert cfg.param_count == pytest.approx(total_b, rel=tol), \
        f"{arch}: {cfg.param_count/1e9:.2f}B vs expected {total_b/1e9}B"
    assert cfg.active_param_count == pytest.approx(active_b, rel=tol)


def test_assignment_hyperparams():
    c = _module("olmoe-1b-7b").make_config(False)
    assert (c.n_layers, c.d_model, c.n_heads, c.moe.n_experts, c.moe.top_k,
            c.moe.d_ff, c.vocab) == (16, 2048, 16, 64, 8, 1024, 50304)
    c = _module("dbrx-132b").make_config(False)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.moe.n_experts,
            c.moe.top_k, c.d_ff, c.vocab) == (40, 6144, 48, 8, 16, 4, 10752, 100352)
    c = _module("nemotron-4-15b").make_config(False)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.act, c.gated) == (32, 6144, 48, 8, 24576, 256000, "relu2", False)
    c = _module("qwen2-0.5b").make_config(False)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.attn_bias) == (24, 896, 14, 2, 4864, True)
    c = _module("minicpm3-4b").make_config(False)
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff) == (62, 2560, 40, 6400)
    assert (c.mla.q_lora_rank, c.mla.kv_lora_rank, c.mla.qk_nope_dim,
            c.mla.qk_rope_dim) == (768, 256, 64, 32)
    c = _module("dimenet").make_config(False)
    assert (c.n_blocks, c.d_hidden, c.n_bilinear, c.n_spherical,
            c.n_radial) == (6, 128, 8, 7, 6)
    c = _module("xdeepfm").make_config(False)
    assert (c.n_sparse, c.embed_dim, c.cin_layers, c.mlp) == (39, 10, (200, 200, 200), (400, 400))
    c = _module("dlrm-rm2").make_config(False)
    assert (c.n_dense, c.n_sparse, c.embed_dim, c.bot_mlp, c.top_mlp) == (
        13, 26, 64, (512, 256, 64), (512, 512, 256, 1))
    c = _module("mind").make_config(False)
    assert (c.embed_dim, c.n_interests, c.capsule_iters) == (64, 4, 3)
    c = _module("bert4rec").make_config(False)
    assert (c.embed_dim, c.n_blocks, c.n_heads, c.seq_len) == (64, 2, 2, 200)
