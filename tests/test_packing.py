"""Bit-packing tests: round-trip across every bit width 1-8 (incl. the
awkward 3-bit case), empty arrays, ragged tails, and bit-exact equivalence
between the vectorized packer and the original bit-matrix reference."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.packing import (
    pack_bits,
    pack_bits_reference,
    packed_nbytes,
    unpack_bits,
    unpack_bits_reference,
)

ALL_BITS = list(range(1, 9))
# deliberately awkward sizes: empty, single, sub-group, non-multiples of the
# 8-code group and of 8//bits, plus a large bulk size
SIZES = [0, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1000, 4096, 100003]


@pytest.mark.parametrize("bits", ALL_BITS)
def test_roundtrip_all_widths_and_sizes(bits):
    rng = np.random.default_rng(bits)
    for n in SIZES:
        codes = rng.integers(0, 1 << bits, size=n).astype(np.uint8)
        buf = pack_bits(codes, bits)
        assert len(buf) == packed_nbytes(n, bits)
        out = unpack_bits(buf, bits, n)
        assert out.dtype == np.uint8
        assert np.array_equal(codes, out), (bits, n)


@pytest.mark.parametrize("bits", ALL_BITS)
def test_equivalence_with_reference_impl(bits):
    """New packer must produce byte-identical streams to the original
    bit-matrix implementation (same wire format, old checkpoints restore)."""
    rng = np.random.default_rng(100 + bits)
    for n in SIZES:
        codes = rng.integers(0, 1 << bits, size=n).astype(np.uint8)
        assert pack_bits(codes, bits) == pack_bits_reference(codes, bits)
        buf = pack_bits_reference(codes, bits)
        assert np.array_equal(unpack_bits(buf, bits, n),
                              unpack_bits_reference(buf, bits, n))


@pytest.mark.parametrize("bits", ALL_BITS)
def test_extreme_codes(bits):
    """All-zeros and all-max codes survive the round trip."""
    top = (1 << bits) - 1
    for codes in (np.zeros(37, np.uint8), np.full(37, top, np.uint8)):
        out = unpack_bits(pack_bits(codes, bits), bits, len(codes))
        assert np.array_equal(codes, out)


def test_empty_array():
    for bits in ALL_BITS:
        assert pack_bits(np.zeros(0, np.uint8), bits) == b""
        assert unpack_bits(b"", bits, 0).size == 0


def test_2d_input_flattens_row_major():
    codes = np.arange(16, dtype=np.uint8).reshape(4, 4) % 8
    assert pack_bits(codes, 3) == pack_bits(codes.reshape(-1), 3)


def test_3bit_density():
    # 8 three-bit codes must fit exactly 3 bytes
    assert packed_nbytes(8, 3) == 3
    assert packed_nbytes(9, 3) == 4
    assert len(pack_bits(np.arange(8, dtype=np.uint8) % 8, 3)) == 3


def test_3bit_known_vector():
    """Little-endian bit order: codes [1,2,3,4,5,6,7,0] -> known bytes."""
    codes = np.array([1, 2, 3, 4, 5, 6, 7, 0], np.uint8)
    want = 0
    for j, c in enumerate(codes):
        want |= int(c) << (3 * j)
    assert pack_bits(codes, 3) == int(want).to_bytes(3, "little")


def test_out_of_range_rejected():
    with pytest.raises(ValueError):
        pack_bits(np.array([4], np.uint8), 2)
    with pytest.raises(ValueError):
        pack_bits(np.array([1], np.uint8), 0)
    with pytest.raises(ValueError):
        pack_bits(np.array([1], np.uint8), 9)


@settings(max_examples=50, deadline=None)
@given(bits=st.integers(1, 8), n=st.integers(0, 2000),
       seed=st.integers(0, 2**31 - 1))
def test_roundtrip_property(bits, n, seed):
    r = np.random.default_rng(seed)
    codes = r.integers(0, 1 << bits, size=n).astype(np.uint8)
    buf = pack_bits(codes, bits)
    assert len(buf) == packed_nbytes(n, bits)
    assert buf == pack_bits_reference(codes, bits)
    assert np.array_equal(unpack_bits(buf, bits, n), codes)
