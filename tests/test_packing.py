"""Bit-packing property tests."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.packing import pack_bits, packed_nbytes, unpack_bits


@settings(max_examples=50, deadline=None)
@given(bits=st.integers(1, 8), n=st.integers(0, 2000),
       seed=st.integers(0, 2**31 - 1))
def test_roundtrip(bits, n, seed):
    r = np.random.default_rng(seed)
    codes = r.integers(0, 1 << bits, size=n).astype(np.uint8)
    buf = pack_bits(codes, bits)
    assert len(buf) == packed_nbytes(n, bits)
    out = unpack_bits(buf, bits, n)
    assert np.array_equal(codes, out)


def test_3bit_density():
    # 8 three-bit codes must fit exactly 3 bytes
    assert packed_nbytes(8, 3) == 3
    assert packed_nbytes(9, 3) == 4


def test_out_of_range_rejected():
    import pytest
    with pytest.raises(ValueError):
        pack_bits(np.array([4], np.uint8), 2)
