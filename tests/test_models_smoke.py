"""Per-architecture smoke tests: every assigned (arch × shape) cell at a
reduced config — one step on CPU, output shapes + finite values."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, arch_shapes, get_cell
from repro.data.cells import batch_for_cell
from tests.conftest import cell_shard

# multi-minute training-stack tests: excluded from the fast CI set
# (`-m "not slow"`), exercised by the scheduled full job — sharded across
# a CI matrix via CNR_CELL_SHARD="i/n" (see conftest.cell_shard)
pytestmark = pytest.mark.slow

CELLS = [(a, s) for a in ARCHS for s in arch_shapes(a)]
SHARD_CELLS = cell_shard(CELLS)


@pytest.mark.parametrize("arch,shape", SHARD_CELLS,
                         ids=[f"{a}-{s}" for a, s in SHARD_CELLS])
def test_cell_smoke(arch, shape):
    bundle = get_cell(arch, shape, reduced=True)
    batch = batch_for_cell(bundle, 0)

    specs = bundle.make_inputs()
    flat_s = jax.tree_util.tree_leaves(specs)
    flat_b = jax.tree_util.tree_leaves(batch)
    assert len(flat_s) == len(flat_b)
    for s, v in zip(flat_s, flat_b):
        assert tuple(s.shape) == tuple(np.shape(v)), (s.shape, np.shape(v))

    if bundle.kind == "train":
        state = bundle.make_state()
        state2, metrics = jax.jit(bundle.step_fn)(state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        assert np.isfinite(loss)
        assert int(jax.device_get(state2.step)) == 1
        # tracked masks exist and match spec sizes
        for name, spec in bundle.tracked.items():
            assert state2.touched[name].shape == (spec.units,)
    else:
        params = bundle.init(jax.random.key(0))
        out = jax.jit(bundle.step_fn)(params, batch)
        for leaf in jax.tree_util.tree_leaves(out):
            arr = np.asarray(jax.device_get(leaf))
            if np.issubdtype(arr.dtype, np.floating):
                assert np.all(np.isfinite(arr.astype(np.float32)))


@pytest.mark.parametrize("arch",
                         cell_shard(["dlrm-rm2", "bert4rec", "olmoe-1b-7b"]))
def test_loss_decreases(arch):
    """A few steps of training reduce the loss on the synthetic stream."""
    shape = "train_batch" if arch != "olmoe-1b-7b" else "train_4k"
    bundle = get_cell(arch, shape, reduced=True)
    state = bundle.make_state()
    step = jax.jit(bundle.step_fn)
    losses = []
    for i in range(15):
        state, m = step(state, batch_for_cell(bundle, i % 3))
        losses.append(float(jax.device_get(m["loss"])))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_registry_covers_40_cells():
    assert len(CELLS) == 40
    assert len(ARCHS) == 10
