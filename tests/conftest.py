"""Shared test fixtures.

If the optional ``hypothesis`` package is unavailable (it is not baked into
the CI image), install a minimal stub so property-based test modules still
import and their deterministic tests still run; only the ``@given`` tests
are skipped.
"""

import os
import sys
import types

import numpy as np
import pytest


def cell_shard(items):
    """Filter a nightly cell list down to this CI shard.

    ``CNR_CELL_SHARD="i/n"`` keeps items round-robin (``index % n == i``)
    so each shard gets an even mix of cheap and expensive architectures
    rather than a contiguous run of the slowest ones. Unset (the default,
    and every local run) returns everything.
    """
    spec = os.environ.get("CNR_CELL_SHARD", "")
    if not spec:
        return list(items)
    i, n = (int(x) for x in spec.split("/"))
    if not 0 <= i < n:
        raise ValueError(f"bad CNR_CELL_SHARD {spec!r}: want i/n with 0<=i<n")
    return [item for k, item in enumerate(items) if k % n == i]


@pytest.fixture
def tiny_snapshot():
    """Factory for tiny default-shaped snapshots — the standard fast-CI
    workload for checkpoint-path tests. Shapes stay small (hundreds of rows,
    single-digit dims) so sharded/fault-injection tests run in milliseconds;
    ragged row counts across tables exercise uneven shard bounds."""
    from repro.core.snapshot import Snapshot

    def make(step=1, rows=300, dim=8, tables=2, seed=0, touched=None,
             with_dense=True, with_aux=True):
        rng = np.random.default_rng(seed)
        tabs = {f"emb{i}": rng.normal(size=(rows + 37 * i, dim))
                .astype(np.float32) for i in range(tables)}
        row_state = {n: ({"acc": np.abs(rng.normal(size=t.shape[0]))
                          .astype(np.float32)} if with_aux else {})
                     for n, t in tabs.items()}
        if touched is None:
            touched = {n: np.ones(t.shape[0], bool) for n, t in tabs.items()}
        dense = ({"mlp/w": rng.normal(size=(16, 16)).astype(np.float32),
                  "mlp/b": rng.normal(size=(16,)).astype(np.float32)}
                 if with_dense else {})
        return Snapshot(step=step, tables=tabs, row_state=row_state,
                        touched=touched, dense=dense, extra={})

    return make

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder; strategies are built at import time but only
        consumed by @given, which the stub turns into a skip."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    _strategy = _Strategy()
    for _name in ("integers", "floats", "sampled_from", "booleans", "lists",
                  "tuples", "just", "one_of", "none", "text", "composite"):
        setattr(_st, _name, _strategy)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
