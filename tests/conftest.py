"""Shared test fixtures.

If the optional ``hypothesis`` package is unavailable (it is not baked into
the CI image), install a minimal stub so property-based test modules still
import and their deterministic tests still run; only the ``@given`` tests
are skipped.
"""

import sys
import types

import pytest

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder; strategies are built at import time but only
        consumed by @given, which the stub turns into a skip."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    _strategy = _Strategy()
    for _name in ("integers", "floats", "sampled_from", "booleans", "lists",
                  "tuples", "just", "one_of", "text", "composite"):
        setattr(_st, _name, _strategy)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
