"""Remote object-store unit tests: transport contract, retry taxonomy,
idempotent multipart, write-through visibility verify, HTTP front-end."""

import os
import threading
import time

import pytest

from repro.core.object_server import serve
from repro.core.remote_store import (
    ChecksumMismatchError,
    FatalTransportError,
    FaultSpec,
    FaultyTransport,
    RemoteObjectStore,
    RemoteVerifyError,
    Response,
    RetriesExhaustedError,
    RetryPolicy,
    ServerBusyError,
    ServerTransport,
    ThrottledTransport,
    Transport,
    TransportConnectionReset,
    TransportTimeout,
    make_store,
    obj_path,
    wrap_faulty,
)
from repro.core.storage import InMemoryStore, LocalFSStore

FAST = RetryPolicy(attempts=6, base_s=0.0005, cap_s=0.005)


def make_remote(part_size=1 << 20, retry=FAST, **kw):
    return RemoteObjectStore(ServerTransport(), part_size=part_size,
                             retry=retry, **kw)


# ------------------------------------------------------------ basic surface
def test_object_store_surface_roundtrip():
    st = make_remote()
    st.put("chunks/a", b"hello")
    assert st.get("chunks/a") == b"hello"
    assert st.exists("chunks/a")
    assert st.size("chunks/a") == 5
    assert st.list("chunks/") == ["chunks/a"]
    assert st.counters.bytes_written == 5
    st.delete("chunks/a")
    assert not st.exists("chunks/a")
    st.delete("chunks/a")  # delete of a missing key is a no-op
    with pytest.raises(KeyError):
        st.get("chunks/a")
    with pytest.raises(KeyError):
        st.size("chunks/a")


def test_put_many_get_many_roundtrip():
    st = make_remote()
    items = [(f"chunks/k{i:03d}", bytes([i]) * (i + 1)) for i in range(17)]
    st.put_many(items, max_workers=4)
    assert st.get_many([k for k, _ in items]) == [d for _, d in items]


# --------------------------------------------------------------- multipart
def test_multipart_roundtrip_and_threshold():
    st = make_remote(part_size=100)
    small = os.urandom(100)           # == part_size → single-shot
    big = os.urandom(1001)            # 11 parts
    st.put("chunks/small", small)
    st.put("chunks/big", big)
    assert st.get("chunks/small") == small
    assert st.get("chunks/big") == big
    assert st.size("chunks/big") == 1001


def test_multipart_duplicate_complete_is_idempotent():
    """A retried complete after the first applied (and upload state was
    reaped) must succeed against the existing object — the response-lost
    delivery path."""
    transport = ServerTransport()
    st = RemoteObjectStore(transport, part_size=64, retry=FAST)
    data = os.urandom(300)
    st.put("chunks/a", data)
    import json
    import zlib
    crc = f"{zlib.crc32(data) & 0xFFFFFFFF:08x}"
    uid = f"{crc}-{len(data)}"
    parts = [[i // 64 + 1,
              f"{zlib.crc32(data[i:i + 64]) & 0xFFFFFFFF:08x}"]
             for i in range(0, len(data), 64)]
    body = json.dumps({"parts": parts}).encode()
    resp = transport.request(
        "POST", f"/mpu/chunks/a", body=body,
        params={"uploadId": uid, "action": "complete", "crc": crc})
    assert resp.status == 200
    assert st.get("chunks/a") == data
    # a duplicate complete with a DIFFERENT crc must refuse (409 → fatal)
    resp = transport.request(
        "POST", f"/mpu/chunks/a", body=body,
        params={"uploadId": uid, "action": "complete", "crc": "00000000"})
    assert resp.status == 409


def test_retried_identical_put_is_byte_safe():
    """Same key, same bytes, delivered twice (duplicate commit-time put):
    second delivery is absorbed, bytes unchanged."""
    st = make_remote(part_size=64)
    data = os.urandom(200)
    st.put("manifests/ckpt_000000000001.json", data)
    st.put("manifests/ckpt_000000000001.json", data)
    assert st.get("manifests/ckpt_000000000001.json") == data


def test_partial_upload_never_visible():
    """A body that arrives truncated fails the declared-checksum test and
    is discarded server-side — no torn object."""
    transport = ServerTransport()
    resp = transport.request("PUT", obj_path("chunks/a"),
                             body=b"torn-fragment",
                             params={"crc": "00000001"})  # wrong on purpose
    assert resp.status == 400
    assert not transport.backing.exists("chunks/a")


# ------------------------------------------------------------- retry logic
class _ScriptedTransport(Transport):
    """Yields scripted outcomes (exceptions or Responses) in order; then
    delegates to an inner ServerTransport."""

    def __init__(self, script):
        self.script = list(script)
        self.inner = ServerTransport()
        self.calls = 0

    def request(self, method, path, body=b"", params=None, timeout_s=None):
        self.calls += 1
        if self.script:
            item = self.script.pop(0)
            if isinstance(item, Exception):
                raise item
            return item
        return self.inner.request(method, path, body=body, params=params,
                                  timeout_s=timeout_s)


@pytest.mark.parametrize("fault", [
    TransportTimeout("t"), TransportConnectionReset("r"),
    Response(503, b"unavailable"), Response(429, b"slow down"),
])
def test_transient_faults_retry_and_succeed(fault):
    t = _ScriptedTransport([fault, fault])
    st = RemoteObjectStore(t, retry=FAST)
    st.put("chunks/a", b"data")
    assert st.get("chunks/a") == b"data"
    assert st.stats.retries >= 2


def test_fatal_4xx_does_not_retry():
    t = _ScriptedTransport([Response(403, b"denied")])
    st = RemoteObjectStore(t, retry=FAST)
    with pytest.raises(FatalTransportError, match="403"):
        st.put("chunks/a", b"data")
    assert t.calls == 1                  # exactly one attempt — no retry


def test_retries_exhausted_surfaces_with_cause():
    t = _ScriptedTransport([TransportConnectionReset(f"r{i}")
                            for i in range(100)])
    st = RemoteObjectStore(t, retry=RetryPolicy(attempts=3, base_s=0.0005))
    with pytest.raises(RetriesExhaustedError) as ei:
        st.put("chunks/a", b"data")
    assert isinstance(ei.value.__cause__, TransportConnectionReset)
    assert t.calls == 3


def test_get_checksum_mismatch_is_fatal():
    t = _ScriptedTransport([Response(200, b"corrupted",
                                     {"etag": "deadbeef"})])
    st = RemoteObjectStore(t, retry=FAST)
    with pytest.raises(ChecksumMismatchError):
        st.get("chunks/a")


def test_backoff_is_capped_exponential_with_jitter():
    p = RetryPolicy(attempts=8, base_s=0.01, cap_s=0.05, jitter=0.5)
    d1, d4 = p.backoff(1), p.backoff(4)
    assert 0.01 <= d1 <= 0.015
    assert 0.05 <= d4 <= 0.075           # capped at cap_s before jitter
    assert p.backoff(7) <= 0.075
    nojit = RetryPolicy(base_s=0.01, jitter=0.0)
    assert nojit.backoff(2) == 0.02      # deterministic without jitter


def test_connection_pool_bounds_concurrency():
    gate_max = []

    class Counting(Transport):
        def __init__(self):
            self.inner = ServerTransport()
            self.inflight = 0
            self.lock = threading.Lock()

        def request(self, method, path, body=b"", params=None,
                    timeout_s=None):
            with self.lock:
                self.inflight += 1
                gate_max.append(self.inflight)
            time.sleep(0.002)
            try:
                return self.inner.request(method, path, body=body,
                                          params=params)
            finally:
                with self.lock:
                    self.inflight -= 1

    st = RemoteObjectStore(Counting(), retry=FAST, max_connections=2)
    st.put_many([(f"chunks/k{i}", b"x") for i in range(12)], max_workers=8)
    assert max(gate_max) <= 2


# ------------------------------------------------- write-through visibility
def test_vote_and_manifest_puts_verify_readback():
    st = make_remote()
    st.put("parts/ckpt_000000000001/host_0000.json", b"vote")
    st.put("manifests/ckpt_000000000001.json", b"manifest")
    assert st.stats.verify_gets == 2
    st.put("chunks/bulk", b"payload")
    assert st.stats.verify_gets == 2     # bulk keys skip the verify


def test_verify_raises_on_divergent_readback():
    class Lying(ServerTransport):
        def request(self, method, path, body=b"", params=None,
                    timeout_s=None):
            resp = super().request(method, path, body=body, params=params)
            if method == "GET" and path.startswith("/o/parts/"):
                return Response(200, b"someone-else's bytes")
            return resp

    st = RemoteObjectStore(Lying(), retry=FAST)
    with pytest.raises(RemoteVerifyError, match="reads back"):
        st.put("parts/ckpt_000000000001/host_0000.json", b"vote")


def test_verify_waits_out_delayed_visibility():
    """A key that turns visible only after a few readbacks still verifies
    (bounded retries with backoff) instead of failing fast."""
    class Delayed(ServerTransport):
        def __init__(self):
            super().__init__()
            self.hidden = 2

        def request(self, method, path, body=b"", params=None,
                    timeout_s=None):
            if (method == "GET" and path.startswith("/o/parts/")
                    and self.hidden > 0):
                self.hidden -= 1
                return Response(404, b"not yet visible")
            return super().request(method, path, body=body, params=params)

    st = RemoteObjectStore(Delayed(), retry=FAST)
    st.put("parts/ckpt_000000000001/host_0000.json", b"vote")  # no raise


# --------------------------------------------------------- fault injection
def test_faultspec_parse_roundtrip():
    spec = FaultSpec(seed=7, error_rate=0.2, partial_put_rate=0.1,
                     slow_rate=0.05, slow_s=0.01, list_lag=3)
    again = FaultSpec.parse(spec.to_arg())
    for f in FaultSpec.FIELDS:
        assert getattr(again, f) == getattr(spec, f)
    with pytest.raises(ValueError):
        FaultSpec.parse("bogus_field=1")


def test_faulty_transport_is_deterministic():
    def run():
        st = make_remote()
        inj = wrap_faulty(st, FaultSpec(seed=11, error_rate=0.3,
                                        partial_put_rate=0.1))
        for i in range(20):
            st.put(f"chunks/k{i}", bytes([i]) * 50)
        return inj.injected, st.stats.retries

    assert run() == run()


def test_faulty_transport_survives_20pct_and_data_is_intact():
    st = make_remote()
    inj = wrap_faulty(st, FaultSpec(seed=3, error_rate=0.2,
                                    partial_put_rate=0.05))
    blobs = {f"chunks/k{i}": os.urandom(100 + i) for i in range(40)}
    for k, d in blobs.items():
        st.put(k, d)
    for k, d in blobs.items():
        assert st.get(k) == d
    assert inj.injected > 0              # faults actually fired
    assert st.stats.retries >= inj.injected - 1


def test_list_visibility_lag_resolves():
    st = make_remote()
    wrap_faulty(st, FaultSpec(seed=0, list_lag=2))
    st.put("chunks/a", b"x")
    first = st.list("chunks/")           # epochs 1,2 hide the fresh key
    assert "chunks/a" not in first
    st.list("chunks/")
    assert st.list("chunks/") == ["chunks/a"]


def test_slow_request_beyond_budget_times_out_and_retries():
    st = RemoteObjectStore(ServerTransport(), retry=FAST, timeout_s=0.01)
    inj = wrap_faulty(st, FaultSpec(seed=5, slow_rate=0.3, slow_s=10.0))
    for i in range(10):
        st.put(f"chunks/k{i}", b"y" * 20)
        assert st.get(f"chunks/k{i}") == b"y" * 20
    assert inj.injected > 0


# ------------------------------------------------------- throttled transport
def test_throttled_transport_paces_uploads():
    st = RemoteObjectStore(
        ThrottledTransport(ServerTransport(), write_bytes_per_sec=100_000),
        retry=FAST)
    t0 = time.monotonic()
    st.put("chunks/a", b"x" * 20_000)    # 0.2 s at 100 kB/s
    assert time.monotonic() - t0 >= 0.15


def test_throttled_transport_charges_retransmissions():
    """Retried bodies occupy the link again — amplification costs real
    wall-clock, matching what the benchmark measures."""
    flaky = _ScriptedTransport([TransportConnectionReset("r")] * 2)
    st = RemoteObjectStore(
        ThrottledTransport(flaky, write_bytes_per_sec=100_000),
        retry=RetryPolicy(attempts=5, base_s=0.0005))
    t0 = time.monotonic()
    st.put("chunks/a", b"x" * 10_000)    # 3 transmissions of 0.1 s
    assert time.monotonic() - t0 >= 0.25
    assert st.stats.bytes_sent == 30_000
    assert st.stats.write_amplification(st.counters.bytes_written) == 3.0


# ------------------------------------------------------------ HTTP + factory
def test_http_server_roundtrip_including_multipart():
    server, port = serve()
    try:
        st = make_store(f"http://127.0.0.1:{port}", part_size=256,
                        retry=FAST)
        big = os.urandom(2000)
        st.put("chunks/big", big)
        st.put("parts/ckpt_000000000001/host_0000.json", b"vote")
        assert st.get("chunks/big") == big
        assert st.size("chunks/big") == 2000
        assert st.list("") == ["chunks/big",
                               "parts/ckpt_000000000001/host_0000.json"]
        st.delete("chunks/big")
        assert not st.exists("chunks/big")
        with pytest.raises(KeyError):
            st.get("chunks/big")
    finally:
        server.shutdown()


def test_http_server_durable_backing(tmp_path):
    """--root mode: the server persists through a LocalFSStore, so pods get
    the same crash durability as the shared-FS path."""
    backing = LocalFSStore(str(tmp_path))
    server, port = serve(backing=backing)
    try:
        st = make_store(f"http://127.0.0.1:{port}", retry=FAST)
        st.put("manifests/ckpt_000000000001.json", b"{}")
        assert (tmp_path / "manifests" / "ckpt_000000000001.json").exists()
    finally:
        server.shutdown()


def test_make_store_dispatch(tmp_path):
    assert isinstance(make_store(str(tmp_path)), LocalFSStore)
    assert isinstance(make_store(f"file://{tmp_path}"), LocalFSStore)
    assert make_store(str(tmp_path), batch_fsync=True).batch_fsync
    mem = make_store("mem://")
    assert isinstance(mem, RemoteObjectStore)
    mem.put("k", b"v")
    assert mem.get("k") == b"v"
    with pytest.raises(ValueError, match="host:port"):
        make_store("http://nohost")
