"""End-to-end recovery tests: the paper's central correctness claims.

* fp32 checkpoints → restored training trajectory is EXACTLY the
  uninterrupted one (same batches via reader-state, same params bit-for-bit).
* quantized checkpoints → bounded parameter perturbation, training proceeds.
* reader-trainer gap: restored run consumes exactly the remaining stream.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_cell
from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore, PAPER_DEFAULTS
from repro.data.cells import batch_for_cell
from repro.train.loop import SimulatedFailure, Trainer, TrainerConfig

# Back in the fast push-time set: Trainers share one compiled train step
# per cell (train.loop._jitted_step) and the runs are trimmed to the
# shortest schedules that still cross a checkpoint + failure + recovery.


_CELLS = {}


def get_cell_cached(arch):
    """One bundle per arch for the whole module: every test's Trainers then
    share one compiled train step via train.loop._jitted_step."""
    if arch not in _CELLS:
        _CELLS[arch] = get_cell(arch, "train_batch", reduced=True)
    return _CELLS[arch]


def flat_params(state):
    leaves = jax.tree_util.tree_flatten_with_path(state.params)[0]
    return {jax.tree_util.keystr(p): np.asarray(jax.device_get(l))
            for p, l in leaves}


@pytest.mark.parametrize("arch", ["dlrm-rm2", "bert4rec"])
def test_failure_recovery_bitwise_equal(arch):
    """Kill at step 5, restore from the step-3 checkpoint, retrain → params
    identical to an uninterrupted 6-step run."""
    bundle = get_cell_cached(arch)

    # uninterrupted reference run
    ref_store = InMemoryStore()
    t_ref = Trainer(bundle, ref_store,
                    CheckpointConfig(interval_batches=3, policy="intermittent",
                                     quant=None, async_write=False),
                    TrainerConfig(total_steps=6, use_reader_tier=True))
    t_ref.init_or_restore()
    ref_state = t_ref.run(6)
    t_ref.close()

    # failing run on its own store
    store = InMemoryStore()
    cfg = CheckpointConfig(interval_batches=3, policy="intermittent",
                           quant=None, async_write=False)
    t1 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=6))
    t1.init_or_restore()
    with pytest.raises(SimulatedFailure):
        t1.run(6, fail_at_step=5)
    t1.close()

    # recovery: restore from checkpoint@3, train to 6
    t2 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=6))
    start = t2.init_or_restore()
    assert start == 3
    final = t2.run(3)
    t2.close()

    a, b = flat_params(ref_state), flat_params(final)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_quantized_recovery_bounded_and_trains():
    """Restore from a 4-bit checkpoint: params must differ from the fp32
    checkpoint state only by the quantization error (compare against an
    fp32-checkpoint twin run at the SAME restore step — no training drift),
    and training must continue to finite losses."""
    bundle = get_cell_cached("dlrm-rm2")

    def run_and_restore(quant):
        store = InMemoryStore()
        cfg = CheckpointConfig(interval_batches=3, policy="intermittent",
                               quant=quant, async_write=False)
        t1 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=6))
        t1.init_or_restore()
        with pytest.raises(SimulatedFailure):
            t1.run(6, fail_at_step=5)
        t1.close()
        t2 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=6))
        assert t2.init_or_restore() == 3
        return t2

    tq = run_and_restore(PAPER_DEFAULTS[4])
    tf = run_and_restore(None)
    a, b = flat_params(tf.state), flat_params(tq.state)
    rel_mean = max(np.abs(a[k] - b[k]).mean() / (np.abs(a[k]).mean() + 1e-9)
                   for k in a)
    assert 0 < rel_mean < 0.1   # pure quantization delta, small but nonzero
    final = tq.run(3)
    tq.close()
    tf.close()
    assert np.isfinite(float(jax.device_get(final.step)))


def test_trainer_stall_fraction_small():
    """§3.2: snapshot stall is a tiny fraction of train time (decoupling)."""
    bundle = get_cell_cached("dlrm-rm2")
    store = InMemoryStore()
    t = Trainer(bundle, store,
                CheckpointConfig(interval_batches=3, policy="intermittent",
                                 quant=PAPER_DEFAULTS[4], async_write=True),
                TrainerConfig(total_steps=6))
    t.init_or_restore()
    import time
    t0 = time.monotonic()
    t.run(6)
    total = time.monotonic() - t0
    t.manager.wait()
    t.close()
    assert sum(t.stall_times) < 0.5 * total  # generous bound for CPU CI


def test_touched_masks_reset_after_checkpoint():
    bundle = get_cell_cached("dlrm-rm2")
    store = InMemoryStore()
    t = Trainer(bundle, store,
                CheckpointConfig(interval_batches=3, policy="one_shot",
                                 quant=None, async_write=False),
                TrainerConfig(total_steps=3))
    t.init_or_restore()
    t.run(3)
    # after the step-3 checkpoint the on-device masks are zeroed
    assert all(int(np.asarray(v).sum()) == 0 for v in t.state.touched.values())
    t.close()
