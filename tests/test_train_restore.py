"""End-to-end recovery tests: the paper's central correctness claims.

* fp32 checkpoints → restored training trajectory is EXACTLY the
  uninterrupted one (same batches via reader-state, same params bit-for-bit).
* quantized checkpoints → bounded parameter perturbation, training proceeds.
* reader-trainer gap: restored run consumes exactly the remaining stream.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_cell
from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore, PAPER_DEFAULTS
from repro.data.cells import batch_for_cell
from repro.train.loop import SimulatedFailure, Trainer, TrainerConfig

# multi-minute training-stack tests: excluded from the fast CI set
# (`-m "not slow"`), exercised by the scheduled full job
pytestmark = pytest.mark.slow


def flat_params(state):
    leaves = jax.tree_util.tree_flatten_with_path(state.params)[0]
    return {jax.tree_util.keystr(p): np.asarray(jax.device_get(l))
            for p, l in leaves}


@pytest.mark.parametrize("arch", ["dlrm-rm2", "bert4rec"])
def test_failure_recovery_bitwise_equal(arch):
    """Kill at step 7, restore from the step-5 checkpoint, retrain → params
    identical to an uninterrupted 10-step run."""
    bundle = get_cell(arch, "train_batch", reduced=True)

    # uninterrupted reference run
    ref_store = InMemoryStore()
    t_ref = Trainer(bundle, ref_store,
                    CheckpointConfig(interval_batches=5, policy="intermittent",
                                     quant=None, async_write=False),
                    TrainerConfig(total_steps=10, use_reader_tier=True))
    t_ref.init_or_restore()
    ref_state = t_ref.run(10)
    t_ref.close()

    # failing run on its own store
    store = InMemoryStore()
    cfg = CheckpointConfig(interval_batches=5, policy="intermittent",
                           quant=None, async_write=False)
    t1 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=10))
    t1.init_or_restore()
    with pytest.raises(SimulatedFailure):
        t1.run(10, fail_at_step=7)
    t1.close()

    # recovery: restore from checkpoint@5, train to 10
    t2 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=10))
    start = t2.init_or_restore()
    assert start == 5
    final = t2.run(5)
    t2.close()

    a, b = flat_params(ref_state), flat_params(final)
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_quantized_recovery_bounded_and_trains():
    """Restore from a 4-bit checkpoint: params must differ from the fp32
    checkpoint state only by the quantization error (compare against an
    fp32-checkpoint twin run at the SAME restore step — no training drift),
    and training must continue to finite losses."""
    bundle = get_cell("dlrm-rm2", "train_batch", reduced=True)

    def run_and_restore(quant):
        store = InMemoryStore()
        cfg = CheckpointConfig(interval_batches=4, policy="intermittent",
                               quant=quant, async_write=False)
        t1 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=8))
        t1.init_or_restore()
        with pytest.raises(SimulatedFailure):
            t1.run(8, fail_at_step=6)
        t1.close()
        t2 = Trainer(bundle, store, cfg, TrainerConfig(total_steps=8))
        assert t2.init_or_restore() == 4
        return t2

    tq = run_and_restore(PAPER_DEFAULTS[4])
    tf = run_and_restore(None)
    a, b = flat_params(tf.state), flat_params(tq.state)
    rel_mean = max(np.abs(a[k] - b[k]).mean() / (np.abs(a[k]).mean() + 1e-9)
                   for k in a)
    assert 0 < rel_mean < 0.1   # pure quantization delta, small but nonzero
    final = tq.run(4)
    tq.close()
    tf.close()
    assert np.isfinite(float(jax.device_get(final.step)))


def test_trainer_stall_fraction_small():
    """§3.2: snapshot stall is a tiny fraction of train time (decoupling)."""
    bundle = get_cell("dlrm-rm2", "train_batch", reduced=True)
    store = InMemoryStore()
    t = Trainer(bundle, store,
                CheckpointConfig(interval_batches=5, policy="intermittent",
                                 quant=PAPER_DEFAULTS[4], async_write=True),
                TrainerConfig(total_steps=10))
    t.init_or_restore()
    import time
    t0 = time.monotonic()
    t.run(10)
    total = time.monotonic() - t0
    t.manager.wait()
    t.close()
    assert sum(t.stall_times) < 0.5 * total  # generous bound for CPU CI


def test_touched_masks_reset_after_checkpoint():
    bundle = get_cell("dlrm-rm2", "train_batch", reduced=True)
    store = InMemoryStore()
    t = Trainer(bundle, store,
                CheckpointConfig(interval_batches=3, policy="one_shot",
                                 quant=None, async_write=False),
                TrainerConfig(total_steps=3))
    t.init_or_restore()
    t.run(3)
    # after the step-3 checkpoint the on-device masks are zeroed
    assert all(int(np.asarray(v).sum()) == 0 for v in t.state.touched.values())
    t.close()
