"""Property tests for the commit-time delta index (docs/serving.md).

Two invariants every serving consumer relies on:

* **superset** — the index's claimed touched-row spans always cover every
  row whose bytes actually changed between consecutive steps, under
  arbitrary save/GC interleavings (span compression widens, never
  narrows);
* **cost** — catch-up bytes computed from the index alone match the range
  planner's own estimate for replaying the same suffix.

Hypothesis drives randomized versions when installed; CI stubs it
(conftest), so each property also has pinned deterministic examples that
always run.
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import CheckNRunManager, CheckpointConfig, InMemoryStore
from repro.core import manifest as mf
from repro.core import range_reader as rr
from repro.core.snapshot import Snapshot
from repro.serve.delta_index import (
    MAX_CHUNK_SPANS,
    MAX_SPANS,
    catchup_cost,
    compress_spans,
    delta_of,
    merge_spans,
    touched_union,
)


def spans_cover(spans, rows):
    """True iff every row index in ``rows`` falls inside some span."""
    return all(any(lo <= r < hi for lo, hi in spans) for r in rows)


def span_rows(spans):
    return sum(hi - lo for lo, hi in spans)


# --------------------------------------------------------- compress_spans
def test_compress_spans_exact_runs():
    idx = np.array([0, 1, 2, 7, 8, 20])
    assert compress_spans(idx) == [[0, 3], [7, 9], [20, 21]]


def test_compress_spans_empty_and_single():
    assert compress_spans(np.array([], dtype=np.int64)) == []
    assert compress_spans(np.array([5])) == [[5, 6]]


def test_compress_spans_cap_merges_smallest_gaps():
    # runs at 0, 10, 11, 100 — cap 2 must keep the widest gap (11→100)
    idx = np.array([0, 10, 11, 100])
    assert compress_spans(idx, cap=2) == [[0, 12], [100, 101]]


def test_compress_spans_cap_is_superset_and_deterministic():
    rng = np.random.default_rng(7)
    for _ in range(25):
        idx = np.unique(rng.integers(0, 5000, size=rng.integers(1, 400)))
        spans = compress_spans(idx, cap=8)
        assert spans == compress_spans(idx, cap=8)  # deterministic
        assert len(spans) <= 8
        assert spans_cover(spans, idx)
        # sorted + disjoint
        for a, b in zip(spans, spans[1:]):
            assert a[1] < b[0]
        # JSON-safe plain ints (np.int64 would break manifest dumps)
        assert all(type(v) is int for s in spans for v in s)


@given(st.lists(st.integers(min_value=0, max_value=2000),
                min_size=1, max_size=300),
       st.integers(min_value=1, max_value=32))
@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_compress_spans_superset_property(rows, cap):
    idx = np.unique(np.asarray(rows))
    spans = compress_spans(idx, cap=cap)
    assert len(spans) <= cap
    assert spans_cover(spans, idx)


def test_merge_spans_union_and_cap():
    assert merge_spans([[5, 7], [0, 3], [2, 4], [7, 9]]) == [[0, 4], [5, 9]]
    assert merge_spans([[3, 3], [9, 4]]) == []  # empty/inverted drop
    many = [[10 * i, 10 * i + 1] for i in range(MAX_SPANS + 40)]
    capped = merge_spans(many)
    assert len(capped) <= MAX_SPANS
    assert spans_cover(capped, [s[0] for s in many])


# ---------------------------------------------------- index vs real saves
def drive(policy, touch_plan, rows=220, dim=4, seed=3, gc_keep=None):
    """Save a chain with the given per-step touched fractions; return
    (store, per-step dict of table arrays). ``gc_keep`` applies retention
    after the last save."""
    rng = np.random.default_rng(seed)
    tabs = {"emb0": rng.normal(size=(rows, dim)).astype(np.float32),
            "emb1": rng.normal(size=(rows + 37, dim)).astype(np.float32)}
    store = InMemoryStore()
    mgr = CheckNRunManager(store, CheckpointConfig(
        policy=policy, quant=None, async_write=False, chunk_rows=64,
        keep_latest=10))
    states = {}
    try:
        for step, frac in enumerate(touch_plan, start=1):
            touched = {}
            for name, arr in tabs.items():
                n = max(1, int(arr.shape[0] * frac))
                idx = rng.choice(arr.shape[0], size=n, replace=False)
                arr[idx] += rng.normal(size=(n, dim)).astype(np.float32)
                t = np.zeros(arr.shape[0], bool)
                t[idx] = True
                touched[name] = t
            mgr.save(Snapshot(
                step=step,
                tables={k: v.copy() for k, v in tabs.items()},
                row_state={n: {} for n in tabs}, touched=touched,
                dense={"w": rng.normal(size=(8,)).astype(np.float32)},
                extra={}), block=True)
            states[step] = {k: v.copy() for k, v in tabs.items()}
        if gc_keep is not None:
            mf.apply_retention(store, keep_latest=gc_keep)
    finally:
        mgr.close()
    return store, states


def assert_superset_and_cost(store, states):
    """Core property pair for every committed step of a driven chain."""
    steps = mf.list_steps(store)
    for step in steps:
        man = mf.load(store, step)
        d = delta_of(man)
        prev = step - 1
        if prev in states:
            for name, arr in states[step].items():
                changed = np.flatnonzero(
                    (arr != states[prev][name]).any(axis=1))
                spans = d["tables"][name]["spans"]
                assert spans_cover(spans, changed), (
                    f"step {step} table {name}: changed rows escape the "
                    f"claimed spans")
        # cost: index-only estimate == range planner's estimate
        chain = mf.recovery_chain(store, step)
        for start in range(len(chain)):
            suffix = chain[start:]
            est = catchup_cost(suffix)
            plan = rr.plan_ranges(suffix)
            assert est["nbytes"] == plan.nbytes, (
                f"step {step} suffix {[m.step for m in suffix]}")
            assert est["chunk_bytes"] == plan.chunk_bytes
            assert est["dense_bytes"] == plan.dense_bytes


@pytest.mark.parametrize("policy", ["consecutive", "intermittent",
                                    "one_shot"])
def test_index_superset_and_cost_pinned(policy):
    store, states = drive(policy, [1.0, 0.05, 0.1, 0.02, 0.3, 0.05])
    assert_superset_and_cost(store, states)


def test_index_superset_and_cost_after_gc():
    # retention drops early steps; surviving manifests must still satisfy
    # both properties (cumulative chains lose intermediates by design)
    store, states = drive("intermittent", [1.0, 0.04, 0.04, 0.04, 0.04],
                          gc_keep=2)
    steps = mf.list_steps(store)
    assert len(steps) >= 2
    assert_superset_and_cost(store, states)


def test_version0_derivation_matches_for_legacy_manifests():
    """Strip the stamped index (simulating a pre-PR manifest): delta_of
    must derive a version-0 record that still superset-covers and still
    costs catch-up exactly like the planner (coarser spans are fine)."""
    store, states = drive("consecutive", [1.0, 0.05, 0.1])
    for step in mf.list_steps(store):
        man = mf.load(store, step)
        stamped = delta_of(man)
        man.delta = None
        for rec in man.tables.values():
            for ch in rec.chunks:
                ch.row_spans = None
        derived = delta_of(man)
        assert derived["version"] == 0
        assert stamped["version"] == 1
        for name, t in stamped["tables"].items():
            dt = derived["tables"][name]
            # byte/row totals are chunk-record sums — identical
            assert dt["payload_bytes"] == t["payload_bytes"]
            assert dt["rows_touched"] == t["rows_touched"]
            # derived spans are coarser but must cover the stamped ones
            assert span_rows(dt["spans"]) >= span_rows(t["spans"])
            assert spans_cover(dt["spans"],
                               [lo for lo, _ in t["spans"]]
                               + [hi - 1 for _, hi in t["spans"]])
        assert derived["dense_bytes"] == stamped["dense_bytes"]


def test_touched_union_covers_all_suffix_changes():
    store, states = drive("consecutive", [1.0, 0.05, 0.05, 0.05])
    chain = mf.recovery_chain(store, 4)
    suffix = [m for m in chain if m.step > 1]
    union = touched_union(suffix)
    for name in states[4]:
        changed = np.flatnonzero(
            (states[4][name] != states[1][name]).any(axis=1))
        assert spans_cover(union[name], changed)


def test_incremental_chunk_records_carry_capped_spans():
    store, _ = drive("consecutive", [1.0, 0.3])
    man = mf.load(store, 2)
    assert man.kind == "incremental"
    for rec in man.tables.values():
        for ch in rec.chunks:
            assert ch.row_spans is not None
            assert 1 <= len(ch.row_spans) <= MAX_CHUNK_SPANS
            assert sum(hi - lo for lo, hi in ch.row_spans) >= ch.n_rows
    # full chunks stay range-encoded, no redundant spans
    full = mf.load(store, 1)
    for rec in full.tables.values():
        for ch in rec.chunks:
            assert ch.row_spans is None and ch.row_range is not None


@given(st.lists(st.floats(min_value=0.01, max_value=0.5),
                min_size=2, max_size=6),
       st.integers(min_value=0, max_value=10_000),
       st.sampled_from(["consecutive", "intermittent", "one_shot"]),
       st.one_of(st.none(), st.integers(min_value=1, max_value=3)))
@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_index_properties_random_interleavings(fracs, seed, policy,
                                               gc_keep):
    store, states = drive(policy, [1.0] + fracs, seed=seed,
                          gc_keep=gc_keep)
    assert_superset_and_cost(store, states)
