"""Pipeline semantics, both directions: bounded window, ordering,
cancellation leaves no committed manifest, overlap="cancel" preemption,
worker crashes surfacing as Future exceptions (never a hang), the generic
stage executor's ordered-final-stage contract, and the streaming restore
engine's equivalence + read-throttle modelling."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    CheckNRunManager,
    CheckpointConfig,
    CheckpointCancelled,
    ChunkCorruptionError,
    InMemoryStore,
    RestorePipeline,
    Snapshot,
    StagePipeline,
    ThrottledStore,
    WritePipeline,
)
from repro.core import manifest as mf


def make_snap(step, table, touched_idx=None):
    R = table.shape[0]
    t = np.zeros(R, dtype=bool)
    if touched_idx is not None:
        t[touched_idx] = True
    return Snapshot(step=step, tables={"emb": table.copy()},
                    row_state={"emb": {}}, touched={"emb": t},
                    dense={}, extra={})


# ---------------------------------------------------------------- pipeline


def test_pipeline_results_in_submission_order():
    store = {}
    pipe = WritePipeline(encode_workers=3, write_workers=3)
    for i in range(20):
        delay = 0.01 if i % 2 else 0.0  # odd items encode slower
        pipe.submit(
            (lambda i=i, d=delay: (time.sleep(d), (b"p%d" % i, i))[1]),
            (lambda payload, i=i: store.__setitem__(i, payload)))
    results = pipe.drain()
    pipe.close()
    assert results == list(range(20))
    assert store == {i: b"p%d" % i for i in range(20)}


def test_pipeline_bounded_inflight():
    peak = [0]
    live = [0]
    lock = threading.Lock()

    def encode(i):
        with lock:
            live[0] += 1
            peak[0] = max(peak[0], live[0])
        time.sleep(0.005)
        return b"x" * 10, i

    def write(payload):
        time.sleep(0.005)
        with lock:
            live[0] -= 1

    pipe = WritePipeline(encode_workers=2, write_workers=2, max_inflight=3)
    for i in range(24):
        pipe.submit(lambda i=i: encode(i), write)
    pipe.drain()
    pipe.close()
    assert peak[0] <= 3


def test_encode_crash_surfaces_no_hang():
    """A crash in an encode worker must resurface promptly from drain() and
    from the item's Future — and never deadlock the bounded window."""
    pipe = WritePipeline(encode_workers=2, write_workers=2, max_inflight=2)

    def boom():
        raise RuntimeError("encode worker crashed")

    futs = []
    with pytest.raises(RuntimeError, match="encode worker crashed"):
        for i in range(8):
            futs.append(pipe.submit(
                boom if i == 1 else (lambda: (b"ok", "ok")),
                lambda payload: None))
        pipe.drain()
    pipe.close()
    assert isinstance(futs[1].exception(timeout=5), RuntimeError)
    # every submitted future settled (no hang)
    assert all(f.done() for f in futs)


def test_write_crash_surfaces_no_hang():
    pipe = WritePipeline(encode_workers=2, write_workers=2, max_inflight=2)

    def bad_write(payload):
        raise IOError("store exploded")

    with pytest.raises(IOError, match="store exploded"):
        for i in range(6):
            pipe.submit(lambda: (b"ok", "ok"), bad_write)
        pipe.drain()
    pipe.close()


def test_cancel_mid_pipeline_aborts():
    cancel = threading.Event()
    pipe = WritePipeline(encode_workers=2, write_workers=2, max_inflight=2,
                         cancel=cancel)
    written = []

    def slow_write(payload):
        time.sleep(0.02)
        written.append(payload)

    pipe.submit(lambda: (b"a", 1), slow_write)
    cancel.set()
    with pytest.raises(CheckpointCancelled):
        for i in range(10):
            pipe.submit(lambda: (b"b", 2), slow_write)
        pipe.drain()
    pipe.close()


def test_deadline_aborts():
    pipe = WritePipeline(encode_workers=1, write_workers=1,
                         deadline=time.monotonic() - 1.0)
    with pytest.raises(CheckpointCancelled):
        pipe.submit(lambda: (b"x", 0), lambda p: None)
        pipe.drain()
    pipe.close()


# ------------------------------------------------- generic stage executor


def test_stage_pipeline_three_stages_chain_values():
    pipe = StagePipeline([("a", 2), ("b", 2), ("c", 2)])
    for i in range(12):
        pipe.submit([lambda i=i: i, lambda v: v * 10, lambda v: v + 1])
    results = pipe.drain()
    pipe.close()
    assert results == [i * 10 + 1 for i in range(12)]
    assert pipe.stats.items == 12
    assert set(pipe.stats.busy) == {"a", "b", "c"}


def test_ordered_final_stage_applies_in_submission_order():
    """Middle-stage completion order is scrambled; the ordered final stage
    must still run strictly in submission order."""
    applied = []
    pipe = StagePipeline([("fetch", 4), ("decode", 4), ("apply", 2)],
                         ordered_final=True)
    assert pipe.workers["apply"] == 1  # ordering forces a single applier
    for i in range(30):
        # reverse-staggered decode delays force out-of-order readiness
        delay = 0.012 if i % 3 == 0 else 0.0
        pipe.submit([lambda i=i: i,
                     lambda v, d=delay: (time.sleep(d), v)[1],
                     lambda v: applied.append(v)])
    pipe.drain()
    pipe.close()
    assert applied == list(range(30))


def test_ordered_final_stage_failed_item_never_strands_successors():
    """An item that dies in decode must tombstone its slot so later items
    still reach the ordered applier (no hang, no skipped successors)."""
    applied = []
    pipe = StagePipeline([("fetch", 2), ("decode", 2), ("apply", 1)],
                         ordered_final=True, max_inflight=3)

    def decode(v):
        if v == 1:
            raise RuntimeError("decode crashed")
        return v

    futs = []
    with pytest.raises(RuntimeError, match="decode crashed"):
        for i in range(10):
            futs.append(pipe.submit([lambda i=i: i, decode,
                                     lambda v: applied.append(v)]))
        pipe.drain()
    pipe.close()
    assert all(f.done() for f in futs)
    assert isinstance(futs[1].exception(timeout=5), RuntimeError)
    # item 0 must have applied; the abort cascade may stop any later ones,
    # but whatever applied is in order and gap-free except the failure
    assert applied == sorted(applied)
    assert 1 not in applied


def test_restore_pipeline_bounded_window():
    peak = [0]
    live = [0]
    lock = threading.Lock()

    def fetch(i):
        with lock:
            live[0] += 1
            peak[0] = max(peak[0], live[0])
        return b"x" * 8

    def apply_(v):
        time.sleep(0.004)
        with lock:
            live[0] -= 1

    pipe = RestorePipeline(fetch_workers=3, decode_workers=2, max_inflight=4)
    for i in range(24):
        pipe.submit(lambda i=i: fetch(i), lambda d: d, apply_)
    pipe.drain()
    pipe.close()
    assert peak[0] <= 4
    assert pipe.stats.payload_bytes == 24 * 8


# ------------------------------------------------- streaming restore engine


def _chain_store(rng, rows=4000, dim=16, chunk_rows=700, incs=2):
    """Build baseline + ``incs`` incremental checkpoints; returns
    (store, config, final_step, final_tables_dict)."""
    table = rng.normal(size=(rows, dim)).astype(np.float32)
    acc = np.abs(rng.normal(size=rows)).astype(np.float32)
    store = InMemoryStore()
    # consecutive: every increment stays in the recovery chain → real
    # chain replay (baseline + incs manifests)
    cfg = CheckpointConfig(policy="consecutive", quant=None,
                           async_write=False, chunk_rows=chunk_rows)
    mgr = CheckNRunManager(store, cfg)
    snap = Snapshot(step=1, tables={"emb": table.copy()},
                    row_state={"emb": {"acc": acc.copy()}},
                    touched={"emb": np.ones(rows, bool)}, dense={}, extra={})
    mgr.save(snap).result()
    for s in range(2, 2 + incs):
        idx = rng.choice(rows, rows // 5, replace=False)
        table[idx] += rng.normal(size=(len(idx), dim)).astype(np.float32)
        acc[idx] = np.abs(rng.normal(size=len(idx))).astype(np.float32)
        t = np.zeros(rows, bool)
        t[idx] = True
        mgr.save(Snapshot(step=s, tables={"emb": table.copy()},
                          row_state={"emb": {"acc": acc.copy()}},
                          touched={"emb": t}, dense={}, extra={})).result()
    mgr.close()
    return store, cfg, 1 + incs, {"emb": (table, acc)}


def test_streaming_restore_replays_chain_in_order():
    """Chain replay through the streaming engine: later increments must
    overwrite the baseline even though all chunks fetch/decode
    concurrently — the final state equals the last snapshot exactly."""
    rng = np.random.default_rng(11)
    store, cfg, last, final = _chain_store(rng)
    mgr = CheckNRunManager(store, cfg)
    rs = mgr.restore()
    mgr.close()
    assert rs.step == last and rs.chain_len == last
    table, acc = final["emb"]
    np.testing.assert_array_equal(rs.tables["emb"], table)
    np.testing.assert_array_equal(rs.row_state["emb"]["acc"], acc)
    assert rs.stats is not None and rs.stats["items"] > 0
    assert set(rs.stats["occupancy"]) == {"fetch", "decode", "apply"}


def test_streaming_restore_corrupt_chunk_raises():
    rng = np.random.default_rng(12)
    store, cfg, last, _ = _chain_store(rng, incs=1)
    key = next(k for k in store.list("chunks/") if k.endswith("000000.bin"))
    blob = bytearray(store.get(key))
    blob[7] ^= 0xFF
    store.put(key, bytes(blob))
    mgr = CheckNRunManager(store, cfg)
    # ChunkCorruptionError subclasses IOError (legacy handlers keep
    # working) and carries step/table/key context instead of a bare
    # "checksum mismatch"
    with pytest.raises(IOError, match="crc32-mismatch") as ei:
        mgr.restore()
    err = ei.value
    assert isinstance(err, ChunkCorruptionError)
    assert err.step == 1 and err.key == key and err.kind == "crc32-mismatch"
    mgr.close()


def test_read_throttled_store_models_bandwidth_and_latency():
    inner = InMemoryStore()
    inner.put("a", b"x" * 100_000)
    inner.put("b", b"x" * 100_000)
    # unthrottled reads stay free
    free = ThrottledStore(inner, write_bytes_per_sec=1e12)
    t0 = time.monotonic()
    free.get("a")
    assert time.monotonic() - t0 < 0.05
    # 1 MB/s + 30ms latency → each 100kB get costs ≥ 0.13s; two serial
    # gets share the link (≥ 0.23s total), latency overlaps concurrently
    slow = ThrottledStore(inner, write_bytes_per_sec=1e12,
                          read_bytes_per_sec=1e6, read_latency_s=0.03)
    t0 = time.monotonic()
    slow.get("a")
    one = time.monotonic() - t0
    assert one >= 0.12
    t0 = time.monotonic()
    slow.get("a")
    slow.get("b")
    assert time.monotonic() - t0 >= 0.23


def test_read_throttle_cancellable():
    inner = InMemoryStore()
    inner.put("a", b"x" * 1_000_000)
    cancel = threading.Event()
    slow = ThrottledStore(inner, write_bytes_per_sec=1e12,
                          cancel_event=cancel,
                          read_bytes_per_sec=100_000)  # 10s transfer
    t = threading.Timer(0.1, cancel.set)
    t.start()
    t0 = time.monotonic()
    with pytest.raises(CheckpointCancelled):
        slow.get("a")
    assert time.monotonic() - t0 < 2.0
    t.cancel()


# ------------------------------------------------- manager-level semantics


def test_cancelled_save_commits_no_manifest():
    """Cancellation mid-pipeline must leave the store without a manifest for
    that step (chunk blobs may exist; they are unreachable garbage)."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(20000, 32)).astype(np.float32)
    cancel_evt = threading.Event()
    slow = ThrottledStore(InMemoryStore(), write_bytes_per_sec=100_000,
                          cancel_event=cancel_evt)
    mgr = CheckNRunManager(slow, CheckpointConfig(
        policy="full_only", quant=None, async_write=True, chunk_rows=1024))
    mgr._cancel = cancel_evt
    fut = mgr.save(make_snap(1, table))
    time.sleep(0.1)
    cancel_evt.set()
    res = fut.result()
    assert res.cancelled
    assert mf.latest_step(slow) is None
    mgr.close()


def test_overlap_cancel_preempts_inflight_save():
    """§3.3: with overlap="cancel" a new save preempts the straggler; the
    next checkpoint still restores exactly."""
    rng = np.random.default_rng(1)
    R = 8000
    table = rng.normal(size=(R, 32)).astype(np.float32)
    cancel_evt = threading.Event()
    slow = ThrottledStore(InMemoryStore(), write_bytes_per_sec=50_000,
                          cancel_event=cancel_evt)
    mgr = CheckNRunManager(slow, CheckpointConfig(
        policy="one_shot", quant=None, async_write=True, overlap="cancel",
        chunk_rows=256))
    mgr._cancel = cancel_evt
    f1 = mgr.save(make_snap(1, table, np.arange(R)))
    time.sleep(0.1)
    slow.bw = 1e12
    f2 = mgr.save(make_snap(2, table, np.arange(R)))
    r1, r2 = f1.result(), f2.result()
    assert r1.cancelled and not r2.cancelled
    rs = mgr.restore()
    np.testing.assert_array_equal(rs.tables["emb"], table)
    mgr.close()


def test_worker_crash_surfaces_on_save_future():
    """An encode-stage crash must surface as the save Future's exception."""
    class BrokenStore(InMemoryStore):
        def put(self, key, data):
            if "emb" in key:
                raise RuntimeError("injected store failure")
            super().put(key, data)

    rng = np.random.default_rng(2)
    table = rng.normal(size=(2048, 8)).astype(np.float32)
    mgr = CheckNRunManager(BrokenStore(), CheckpointConfig(
        policy="full_only", quant=None, async_write=True, chunk_rows=256))
    fut = mgr.save(make_snap(1, table))
    with pytest.raises(RuntimeError, match="injected store failure"):
        fut.result(timeout=30)
    mgr.close()


def test_pipelined_and_serial_payloads_identical():
    """The pipelined engine must produce byte-identical chunk blobs and an
    equivalent manifest to the window-of-1 (serial-order) engine."""
    rng = np.random.default_rng(3)
    table = rng.normal(size=(5000, 16)).astype(np.float32)
    acc = np.abs(rng.normal(size=5000)).astype(np.float32)

    def run(pipeline):
        store = InMemoryStore()
        mgr = CheckNRunManager(store, CheckpointConfig(
            policy="full_only", async_write=False, chunk_rows=700,
            pipeline=pipeline, aux_bits=8))
        snap = Snapshot(step=1, tables={"emb": table.copy()},
                        row_state={"emb": {"acc": acc.copy()}},
                        touched={"emb": np.ones(5000, bool)},
                        dense={"w": rng.normal(size=(4, 4)).astype(np.float32)},
                        extra={})
        # rebuild dense deterministically across runs
        snap.dense = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
        mgr.save(snap).result()
        return store

    s_pipe, s_serial = run(True), run(False)
    keys_p = [k for k in s_pipe.list("chunks/")]
    keys_s = [k for k in s_serial.list("chunks/")]
    assert keys_p == keys_s and len(keys_p) >= 9
    for k in keys_p:
        assert s_pipe.get(k) == s_serial.get(k), k
